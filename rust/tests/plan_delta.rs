//! Incremental plan recompile (PR 5 acceptance):
//!
//! (a) `SparsePlan::apply_delta` is **bitwise-identical** to a
//!     from-scratch compile across randomized mask flips, under both
//!     decode modes (including the RowCached == PerAccess equivalence),
//!     serial and pool-fanned, with unchanged row-group segments
//!     structurally shared with the base plan;
//! (b) the engine-level `LayerPlans::delta_from` rebuilds the joint plan
//!     *and* both text/vision row slices identically to a full compile;
//! (c) a full denoising run with delta compilation on is bitwise-identical
//!     to the same run with delta off, and every post-first-refresh miss
//!     is served incrementally (`plan_cache_delta == misses - layers`);
//! (d) regression: a **byte-identical** refresh still takes the plan-cache
//!     hit fast path — no delta compile runs and `delta_hits` stays
//!     unchanged — so the delta machinery never penalizes the PR 2 cache;
//! (e) the batched engine's shared-plan epochs compose with delta: a
//!     shared burst pays one (delta) compile per (layer, refresh) and
//!     stays bitwise-identical to solo runs.

use flashomni::batch::BatchedEngine;
use flashomni::config::{ModelConfig, SparsityConfig};
use flashomni::engine::{DiTEngine, Geometry, LayerPlans, Policy};
use flashomni::exec::ExecPool;
use flashomni::model::{weights::Weights, MiniMMDiT};
use flashomni::plan::cache::symbol_key;
use flashomni::plan::{DecodeMode, PlanDelta, SparsePlan};
use flashomni::symbols::{HeadSymbols, LayerSymbols};
use flashomni::testutil::{prop_check, rand_mask};
use flashomni::workload::{caption_ids, Request};
use flashomni::util::rng::Pcg32;
use std::time::Instant;

/// Random per-head logical masks for one layer.
fn random_masks(
    rng: &mut Pcg32,
    heads: usize,
    qg: usize,
    kg: usize,
) -> Vec<(Vec<bool>, Vec<bool>)> {
    (0..heads)
        .map(|_| (rand_mask(rng, qg, 0.6), rand_mask(rng, qg * kg, 0.5)))
        .collect()
}

/// Flip a random number of row-groups (possibly zero) in each head.
fn flip_masks(rng: &mut Pcg32, masks: &mut [(Vec<bool>, Vec<bool>)], qg: usize, kg: usize) {
    for (m_c, m_s) in masks.iter_mut() {
        let flips = rng.below(qg + 1);
        for _ in 0..flips {
            let g = rng.below(qg);
            if rng.below(2) == 0 {
                m_c[g] = !m_c[g];
            }
            let j = rng.below(kg);
            m_s[g * kg + j] = !m_s[g * kg + j];
        }
    }
}

fn pack(masks: &[(Vec<bool>, Vec<bool>)], kg: usize, pool: usize) -> LayerSymbols {
    LayerSymbols {
        heads: masks
            .iter()
            .map(|(m_c, m_s)| HeadSymbols::from_masks(m_c, m_s, kg, pool))
            .collect(),
    }
}

// ---------------------------------------------------------------- (a) --

#[test]
fn apply_delta_bitwise_matches_full_recompile() {
    prop_check("apply_delta == full compile (bitwise)", 60, |rng| {
        let heads = 1 + rng.below(4);
        let pool = 1 + rng.below(3);
        let t_q = 1 + rng.below(40);
        let t_kv = 1 + rng.below(40);
        let qg = t_q.div_ceil(pool);
        let kg = t_kv.div_ceil(pool);
        let mut masks = random_masks(rng, heads, qg, kg);
        let old = pack(&masks, kg, pool);
        flip_masks(rng, &mut masks, qg, kg);
        let new = pack(&masks, kg, pool);

        let geometry = [t_q, t_kv, 8, 8];
        let old_key = symbol_key(&old, &geometry);
        let new_key = symbol_key(&new, &geometry);
        let delta = PlanDelta::between(&old_key, &new_key, &new, geometry.len())
            .expect("same geometry must be row-diffable");

        let base = SparsePlan::compile(&old, t_q, t_kv, 8, 8, DecodeMode::RowCached);
        let full_rc = SparsePlan::compile(&new, t_q, t_kv, 8, 8, DecodeMode::RowCached);
        let full_pa = SparsePlan::compile(&new, t_q, t_kv, 8, 8, DecodeMode::PerAccess);

        // Serial delta, both decode modes (RowCached == PerAccess holds
        // through the incremental path too).
        let got_rc = base.apply_delta(&delta, &new, DecodeMode::RowCached);
        let got_pa = base.apply_delta(&delta, &new, DecodeMode::PerAccess);
        assert_eq!(got_rc, full_rc, "delta(RowCached) must equal full recompile");
        assert_eq!(got_pa, full_pa, "delta(PerAccess) must equal full recompile");
        assert_eq!(got_rc, got_pa, "decode modes must agree on the delta path");

        // Pool-fanned delta is bitwise-identical to the serial one.
        let got_pool =
            base.apply_delta_on(&delta, &new, DecodeMode::RowCached, &ExecPool::global());
        assert_eq!(got_pool, got_rc);

        // Unchanged row-groups are structurally shared (same Arc), not
        // copied: exactly q_groups − |changed| segments per head.
        for (h, (got_h, base_h)) in got_rc.heads.iter().zip(&base.heads).enumerate() {
            let unchanged = qg - delta.changed(h).len();
            assert_eq!(
                got_h.shared_segments_with(base_h),
                unchanged,
                "head {h}: unchanged segments must be Arc-shared with the base"
            );
        }

        // A byte-identical "refresh" shares every segment.
        let same = PlanDelta::between(&new_key, &new_key, &new, geometry.len()).unwrap();
        assert!(same.is_empty());
        let noop = got_rc.apply_delta(&same, &new, DecodeMode::RowCached);
        assert_eq!(noop, got_rc);
        assert_eq!(noop.shared_segments_with(&got_rc), heads * qg);
    });
}

// ---------------------------------------------------------------- (b) --

#[test]
fn layer_plans_delta_matches_full_compile_including_slices() {
    prop_check("LayerPlans::delta_from == LayerPlans::compile", 30, |rng| {
        let pool = 1 + rng.below(2);
        let heads = 1 + rng.below(3);
        let qg = 2 + rng.below(8);
        let tbg = rng.below(qg + 1); // text prefix in row-groups
        let block = 8;
        let t_q = qg * pool;
        let geo = Geometry {
            block_q: block,
            block_k: block,
            pool,
            text_tokens: tbg * pool * block,
            seq: t_q * block,
        };
        assert_eq!(geo.q_groups(), qg);
        let kg = geo.kv_groups();
        let mut masks = random_masks(rng, heads, qg, kg);
        let old = pack(&masks, kg, pool);
        flip_masks(rng, &mut masks, qg, kg);
        let new = pack(&masks, kg, pool);

        let base = LayerPlans::compile(&old, &geo);
        let got = LayerPlans::delta_from(&base, &new, &geo)
            .expect("same geometry must be row-diffable");
        let want = LayerPlans::compile(&new, &geo);
        assert_eq!(got.joint, want.joint, "joint plan must match full compile");
        assert_eq!(got.txt, want.txt, "text slice must match full compile");
        assert_eq!(got.img, want.img, "vision slice must match full compile");
        assert_eq!(got.key, want.key, "delta result must carry the new key");

        // Base plans under a different geometry are not diffable.
        let other = Geometry { text_tokens: 0, ..geo };
        if geo.text_tokens != 0 {
            assert!(LayerPlans::delta_from(&base, &new, &other).is_none());
        }
    });
}

// ---------------------------------------------------------------- (c) --

fn tiny_model() -> MiniMMDiT {
    // 8×8 patches → 64 vision tokens + 8 text tokens = seq 72, t_q = 9:
    // big enough that per-layer symbol streams don't collide by accident.
    let cfg = ModelConfig {
        dim: 32,
        heads: 2,
        layers: 2,
        text_tokens: 8,
        patch_h: 8,
        patch_w: 8,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 2,
        vocab: 16,
    };
    MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 11))
}

fn scfg() -> SparsityConfig {
    SparsityConfig {
        tau_q: 0.6,
        tau_kv: 0.3,
        interval: 3,
        order: 1,
        s_q: 0.0,
        block_q: 8,
        block_k: 8,
        pool: 1,
        warmup: 2,
        ramp_steps: 1,
    }
}

#[test]
fn delta_on_and_off_generate_identical_images() {
    let model = tiny_model();
    let ids: Vec<usize> = (0..model.cfg.text_tokens).collect();
    let layers = model.cfg.layers as u64;
    let mut on = DiTEngine::new(model.clone(), Policy::flashomni(scfg()), 8, 8);
    let mut off = DiTEngine::new(model, Policy::flashomni(scfg()), 8, 8);
    off.set_delta_compile(false);
    let r_on = on.generate(&ids, 3, 12);
    let r_off = off.generate(&ids, 3, 12);
    assert_eq!(
        r_on.image, r_off.image,
        "delta compilation must not change the output"
    );
    assert_eq!(r_off.stats.plan_cache_delta, 0, "delta disabled must never delta-compile");
    // With delta on, only a layer's *first* refresh of the run (no base
    // plan yet) may compile from scratch — every further miss must be
    // served incrementally. (A layer whose first refresh hits an entry
    // another layer compiled full-compiles zero times, hence the bound.)
    let (misses, deltas) = (r_on.stats.plan_cache_misses, r_on.stats.plan_cache_delta);
    assert!(deltas <= misses, "delta compiles are a subset of misses");
    assert!(
        deltas >= misses.saturating_sub(layers),
        "at most one full compile per layer per run (got {deltas} deltas / {misses} misses)"
    );
    if misses > layers {
        assert!(deltas > 0, "a repeat miss has a base plan and must delta-compile");
    }
    assert_eq!(r_on.stats.plan_cache_misses, r_off.stats.plan_cache_misses);
}

#[test]
fn per_step_mask_policy_rides_the_delta_path() {
    // SpargeAttn-style policies regenerate S_s every Dispatch step from
    // evolving activations — the heaviest recompile traffic, and exactly
    // the slowly-drifting regime delta compilation targets.
    let model = tiny_model();
    let ids: Vec<usize> = (0..model.cfg.text_tokens).collect();
    let layers = model.cfg.layers as u64;
    let mut engine = DiTEngine::new(model, Policy::sparge(0.08, 0.09, 1), 8, 8);
    let res = engine.generate(&ids, 5, 8);
    assert!(res.image.data().iter().all(|x| x.is_finite()));
    let (misses, deltas) = (res.stats.plan_cache_misses, res.stats.plan_cache_delta);
    assert!(deltas <= misses);
    assert!(
        deltas >= misses.saturating_sub(layers),
        "per-step refreshes must delta-compile after each layer's first \
         (got {deltas} deltas / {misses} misses)"
    );
    if misses > layers {
        assert!(deltas > 0, "repeat per-step refreshes must ride the delta path");
    }
}

// ---------------------------------------------------------------- (d) --

#[test]
fn byte_identical_refresh_keeps_the_hit_fast_path() {
    let model = tiny_model();
    let ids: Vec<usize> = (0..model.cfg.text_tokens).collect();
    let mut engine = DiTEngine::new(model, Policy::flashomni(scfg()), 8, 8);
    let r1 = engine.generate(&ids, 3, 10);
    assert!(r1.stats.plan_cache_misses > 0, "first run must compile plans");
    let delta_after_r1 = engine.plan_cache_stats().delta_hits;
    // Identical request → byte-identical symbols → every refresh takes the
    // plain hit path: no misses, no delta compiles, delta_hits unchanged.
    let r2 = engine.generate(&ids, 3, 10);
    assert_eq!(r2.stats.plan_cache_misses, 0, "repeated prompt must hit on every refresh");
    assert_eq!(r2.stats.plan_cache_delta, 0, "a hit must not delta-compile");
    assert!(r2.stats.plan_cache_hits > 0);
    assert_eq!(
        engine.plan_cache_stats().delta_hits,
        delta_after_r1,
        "byte-identical refreshes must leave the cache's delta counter untouched"
    );
    assert_eq!(r1.image, r2.image, "cache reuse must not change the output");
}

// ---------------------------------------------------------------- (e) --

#[test]
fn batched_shared_burst_delta_compiles_once_per_refresh() {
    let model = tiny_model();
    let steps = 10;
    let ids = caption_ids(5, model.cfg.text_tokens);
    let layers = model.cfg.layers as u64;

    let mut solo = DiTEngine::new(model.clone(), Policy::flashomni(scfg()), 8, 8);
    let want = solo.generate(&ids, 1234, steps);

    let mut batch = BatchedEngine::new(model, Policy::flashomni(scfg()), 8, 8, 2);
    for id in 0..2u64 {
        batch.admit(
            Request {
                id,
                scene: 5,
                prompt_ids: ids.clone(),
                seed: 1234,
                steps,
                arrival_s: 0.0,
                patch_hw: None,
            },
            Instant::now(),
        );
    }
    let results = batch.run_to_completion();
    assert_eq!(results.len(), 2);
    for r in &results {
        assert_eq!(
            r.image, want.image,
            "batched + delta output must stay bitwise-identical to solo"
        );
    }
    let misses: u64 = results.iter().map(|r| r.stats.plan_cache_misses).sum();
    let deltas: u64 = results.iter().map(|r| r.stats.plan_cache_delta).sum();
    let shared: u64 = results.iter().map(|r| r.stats.plan_cache_shared).sum();
    let cache = batch.plan_cache_stats();
    assert_eq!(misses, cache.misses, "per-request counters must cover the cache");
    assert_eq!(deltas, cache.delta_hits);
    assert!(shared > 0, "a symbol-identical pair must share compiles");
    assert!(deltas <= misses);
    assert!(
        deltas >= misses.saturating_sub(layers),
        "after each layer's first refresh, the one compile per (layer, refresh) is a delta \
         (got {deltas} deltas / {misses} misses)"
    );
}
