//! Paged-memory acceptance tests (PR 10 tentpole):
//!
//! (a) **property/fuzz** — randomized alloc / intern(share) / clone /
//!     write(CoW) / make_shared / free / purge sequences run against a
//!     *naive reference allocator* that mirrors the pool's semantics with
//!     plain vectors and linear scans. After every op the real pool must
//!     agree exactly: block refcounts, live/resident page accounting,
//!     share/CoW/eviction counters, and every live handle's contents
//!     (no use-after-free, CoW never aliases a shared page). At the end
//!     of every case the pool must drain to zero pages (no leaks).
//!     Failures shrink by **prefix replay**: the shortest failing prefix
//!     of the op sequence is reported with the case seed. Iteration
//!     count is raised in CI via `FO_PAGE_POOL_CASES`.
//! (b) **budget invariance** — a mixed-resolution batched run under a
//!     tight page budget is bitwise-identical to unbudgeted solo runs,
//!     while `RunStats` proves real pressure (evictions > 0) and real
//!     prefix sharing (share hits > 0, identical pair one physical copy).
//! (c) **key dedupe** — a shared-batch refresh interns the packed symbol
//!     key once; every other lane refcounts that block (regression for
//!     the old PlanCache-map-key + LayerPlans.key double allocation).

use flashomni::batch::BatchedEngine;
use flashomni::config::{ModelConfig, SparsityConfig};
use flashomni::engine::{DiTEngine, Policy, RunStats};
use flashomni::mem::{Digest, PagePool, Pooled};
use flashomni::model::{weights::Weights, MiniMMDiT};
use flashomni::plan::cache::{Compiled, SharedPlanCache};
use flashomni::tensor::Tensor;
use flashomni::testutil::prop_check;
use flashomni::util::rng::Pcg32;
use flashomni::workload::{caption_ids, Request};
use std::collections::VecDeque;
use std::time::Instant;

// ---------------------------------------------------------------- (a) --

/// One fuzz step. Slot indices (`pick`) are taken modulo the number of
/// live slots *at execution time*, so a prefix of an op sequence always
/// replays deterministically — that is what makes prefix shrinking sound.
#[derive(Clone, Copy, Debug)]
enum Op {
    /// Private allocation of `vec![fill; len]`.
    Alloc { len: usize, fill: u8 },
    /// Content-interned allocation (prefix sharing on equal content).
    Intern { ns: u8, len: usize, fill: u8 },
    /// Clone an existing handle (refcount bump, no bytes).
    CloneOf { pick: usize },
    /// Write one byte through `make_mut` (CoW when shared/keyed).
    Write { pick: usize, pos: usize, val: u8 },
    /// Promote a handle to a shared block under (ns, content).
    MakeShared { pick: usize, ns: u8 },
    /// Drop a handle.
    Free { pick: usize },
    /// Drop every retained block.
    Purge,
}

/// Digest the fuzzer uses for interning: namespaced like the engine's
/// `b"plankey"` / `b"taylor"` keys, content-hashed like `intern_bytes`.
fn fuzz_digest(ns: u8, bytes: &[u8]) -> [u8; 16] {
    let mut d = Digest::new(&[b'f', b'z', ns]);
    d.update(bytes);
    d.finish()
}

/// The reference model of one block: contents, namespace key, refcount,
/// page footprint, retained flag — nothing clever, everything explicit.
struct RefBlock {
    bytes: Vec<u8>,
    /// Intern namespace. Keyed blocks are never mutated in place (writes
    /// CoW), so `(key, bytes)` is the block's stable intern identity.
    key: Option<u8>,
    refs: u64,
    pages: u64,
    retained: bool,
}

/// Naive reference allocator: linear scans instead of digest maps, a
/// `Vec<Option<Block>>` instead of an id table, but byte-for-byte the
/// same visible semantics as `PagePool`.
struct RefAlloc {
    page_bytes: usize,
    budget: u64,
    blocks: Vec<Option<RefBlock>>,
    fifo: VecDeque<usize>,
    live_pages: u64,
    resident_pages: u64,
    blocks_allocated: u64,
    pages_allocated: u64,
    share_hits: u64,
    cow_copies: u64,
    blocks_evicted: u64,
    pages_evicted: u64,
}

impl RefAlloc {
    fn new(budget: u64, page_bytes: usize) -> RefAlloc {
        RefAlloc {
            page_bytes,
            budget,
            blocks: Vec::new(),
            fifo: VecDeque::new(),
            live_pages: 0,
            resident_pages: 0,
            blocks_allocated: 0,
            pages_allocated: 0,
            share_hits: 0,
            cow_copies: 0,
            blocks_evicted: 0,
            pages_evicted: 0,
        }
    }

    fn pages_for(&self, len: usize) -> u64 {
        len.max(1).div_ceil(self.page_bytes) as u64
    }

    fn evict_one(&mut self, id: usize) {
        let b = self.blocks[id].take().expect("evictable block exists");
        self.resident_pages -= b.pages;
        self.blocks_evicted += 1;
        self.pages_evicted += b.pages;
    }

    fn evict_for(&mut self, extra: u64) {
        if self.budget == 0 {
            return;
        }
        while self.resident_pages + extra > self.budget {
            let Some(id) = self.fifo.pop_front() else { break };
            let evictable =
                matches!(&self.blocks[id], Some(b) if b.retained && b.refs == 0);
            if evictable {
                self.evict_one(id);
            }
        }
    }

    fn insert(&mut self, bytes: Vec<u8>, key: Option<u8>) -> usize {
        let pages = self.pages_for(bytes.len());
        self.evict_for(pages);
        self.blocks.push(Some(RefBlock { bytes, key, refs: 1, pages, retained: false }));
        self.blocks_allocated += 1;
        self.pages_allocated += pages;
        self.resident_pages += pages;
        self.live_pages += pages;
        self.blocks.len() - 1
    }

    fn find_keyed(&self, ns: u8, bytes: &[u8]) -> Option<usize> {
        self.blocks.iter().position(
            |b| matches!(b, Some(b) if b.key == Some(ns) && b.bytes == bytes),
        )
    }

    /// Bump an intern hit: refcount up, resurrect if retained.
    fn bump(&mut self, id: usize) {
        let b = self.blocks[id].as_mut().expect("hit block exists");
        b.refs += 1;
        if std::mem::take(&mut b.retained) {
            self.live_pages += b.pages;
        }
    }

    fn intern(&mut self, ns: u8, bytes: Vec<u8>) -> (usize, bool) {
        if let Some(id) = self.find_keyed(ns, &bytes) {
            self.bump(id);
            self.share_hits += 1;
            (id, true)
        } else {
            (self.insert(bytes, Some(ns)), false)
        }
    }

    fn clone_ref(&mut self, id: usize) {
        let b = self.blocks[id].as_mut().expect("cloned handle's block exists");
        assert!(!b.retained && b.refs > 0, "clone of a live handle");
        b.refs += 1;
    }

    fn release(&mut self, id: usize) {
        let b = self.blocks[id].as_mut().expect("released block exists");
        b.refs -= 1;
        if b.refs > 0 {
            return;
        }
        if b.key.is_some() && self.budget > 0 {
            b.retained = true;
            self.live_pages -= b.pages;
            self.fifo.push_back(id);
            self.evict_for(0);
        } else {
            let b = self.blocks[id].take().expect("still present");
            self.resident_pages -= b.pages;
            self.live_pages -= b.pages;
        }
    }

    /// Mirror `make_mut` + one byte write. Returns the slot's new block id.
    fn write(&mut self, id: usize, pos: usize, val: u8) -> usize {
        let b = self.blocks[id].as_ref().expect("written block exists");
        if b.refs == 1 && b.key.is_none() {
            self.blocks[id].as_mut().expect("checked").bytes[pos] = val;
            return id;
        }
        let mut nb = b.bytes.clone();
        nb[pos] = val;
        // Same order as the pool: the copy allocates (and may evict)
        // while the old block is still live, then the old ref drops.
        let nid = self.insert(nb, None);
        self.cow_copies += 1;
        self.release(id);
        nid
    }

    /// Mirror `make_shared`. Returns (new block id, reported sharing).
    fn make_shared(&mut self, id: usize, ns: u8) -> (usize, bool) {
        if self.blocks[id].as_ref().expect("live block").key == Some(ns) {
            return (id, true); // already the interned copy for this key
        }
        let bytes = self.blocks[id].as_ref().expect("live block").bytes.clone();
        if let Some(other) = self.find_keyed(ns, &bytes) {
            self.bump(other);
            self.share_hits += 1;
            self.release(id);
            return (other, true);
        }
        let b = self.blocks[id].as_mut().expect("live block");
        if b.key.is_some() {
            (id, false) // interned under another namespace: stays put
        } else {
            b.key = Some(ns);
            (id, true)
        }
    }

    fn purge(&mut self) {
        while let Some(id) = self.fifo.pop_front() {
            let evictable =
                matches!(&self.blocks[id], Some(b) if b.retained && b.refs == 0);
            if evictable {
                self.evict_one(id);
            }
        }
    }
}

/// A live fuzz slot: the real handle plus its model block id.
struct Slot {
    handle: Pooled<Vec<u8>>,
    bid: usize,
}

/// Compare the real pool against the model after one op.
fn check(i: usize, op: &Op, pool: &PagePool, model: &RefAlloc, slots: &[Slot]) -> Result<(), String> {
    let s = pool.stats();
    let fail = |what: &str, got: u64, want: u64| {
        Err(format!("op {i} {op:?}: {what} = {got}, reference says {want}"))
    };
    if s.live_pages != model.live_pages {
        return fail("live_pages", s.live_pages, model.live_pages);
    }
    if s.resident_pages != model.resident_pages {
        return fail("resident_pages", s.resident_pages, model.resident_pages);
    }
    if s.blocks_allocated != model.blocks_allocated {
        return fail("blocks_allocated", s.blocks_allocated, model.blocks_allocated);
    }
    if s.pages_allocated != model.pages_allocated {
        return fail("pages_allocated", s.pages_allocated, model.pages_allocated);
    }
    if s.share_hits != model.share_hits {
        return fail("share_hits", s.share_hits, model.share_hits);
    }
    if s.cow_copies != model.cow_copies {
        return fail("cow_copies", s.cow_copies, model.cow_copies);
    }
    if s.blocks_evicted != model.blocks_evicted {
        return fail("blocks_evicted", s.blocks_evicted, model.blocks_evicted);
    }
    if s.pages_evicted != model.pages_evicted {
        return fail("pages_evicted", s.pages_evicted, model.pages_evicted);
    }
    if model.budget > 0 && s.resident_pages > model.budget.max(s.live_pages) {
        return Err(format!(
            "op {i} {op:?}: resident {} exceeds budget {} with live {}",
            s.resident_pages, model.budget, s.live_pages
        ));
    }
    for (j, slot) in slots.iter().enumerate() {
        let Some(b) = model.blocks[slot.bid].as_ref() else {
            return Err(format!("op {i} {op:?}: slot {j} points at a freed reference block"));
        };
        if *slot.handle != b.bytes {
            return Err(format!(
                "op {i} {op:?}: slot {j} contents diverged (use-after-free or CoW aliasing): \
                 pool has {:?}.., reference has {:?}..",
                &slot.handle[..slot.handle.len().min(8)],
                &b.bytes[..b.bytes.len().min(8)]
            ));
        }
        if slot.handle.ref_count() != b.refs {
            return fail("slot refcount", slot.handle.ref_count(), b.refs);
        }
    }
    Ok(())
}

/// Execute an op sequence on a fresh pool + reference model, checking
/// full agreement after every op and a drained pool at the end.
fn run_ops(ops: &[Op], budget: u64, page_bytes: usize) -> Result<(), String> {
    let pool = PagePool::with_budget(budget, page_bytes);
    let mut model = RefAlloc::new(budget, page_bytes);
    let mut slots: Vec<Slot> = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        match *op {
            Op::Alloc { len, fill } => {
                let bytes = vec![fill; len];
                let handle = pool.alloc(len, bytes.clone());
                let bid = model.insert(bytes, None);
                slots.push(Slot { handle, bid });
            }
            Op::Intern { ns, len, fill } => {
                let bytes = vec![fill; len];
                let (handle, shared) =
                    pool.intern_digest(fuzz_digest(ns, &bytes), len, bytes.clone());
                let (bid, want) = model.intern(ns, bytes);
                if shared != want {
                    return Err(format!("op {i} {op:?}: shared={shared}, reference says {want}"));
                }
                slots.push(Slot { handle, bid });
            }
            Op::CloneOf { pick } => {
                if slots.is_empty() {
                    continue;
                }
                let s = pick % slots.len();
                let handle = slots[s].handle.clone();
                let bid = slots[s].bid;
                model.clone_ref(bid);
                slots.push(Slot { handle, bid });
            }
            Op::Write { pick, pos, val } => {
                if slots.is_empty() {
                    continue;
                }
                let s = pick % slots.len();
                let len = slots[s].handle.len();
                if len == 0 {
                    continue;
                }
                let pos = pos % len;
                slots[s].handle.make_mut()[pos] = val;
                slots[s].bid = model.write(slots[s].bid, pos, val);
            }
            Op::MakeShared { pick, ns } => {
                if slots.is_empty() {
                    continue;
                }
                let s = pick % slots.len();
                let bytes = (*slots[s].handle).clone();
                let got = slots[s].handle.make_shared(fuzz_digest(ns, &bytes));
                let (bid, want) = model.make_shared(slots[s].bid, ns);
                slots[s].bid = bid;
                if got != want {
                    return Err(format!(
                        "op {i} {op:?}: make_shared={got}, reference says {want}"
                    ));
                }
            }
            Op::Free { pick } => {
                if slots.is_empty() {
                    continue;
                }
                let s = pick % slots.len();
                let slot = slots.swap_remove(s);
                model.release(slot.bid);
                drop(slot.handle);
            }
            Op::Purge => {
                pool.purge();
                model.purge();
            }
        }
        check(i, op, &pool, &model, &slots)?;
    }
    // No leaks: dropping every handle and purging drains the pool to zero.
    while let Some(slot) = slots.pop() {
        model.release(slot.bid);
        drop(slot.handle);
    }
    pool.purge();
    model.purge();
    let s = pool.stats();
    if s.live_pages != 0 || s.resident_pages != 0 {
        return Err(format!(
            "pool did not drain to zero after dropping every handle: {s:?}"
        ));
    }
    if model.resident_pages != 0 {
        return Err(format!(
            "reference allocator leaked {} pages — model bug",
            model.resident_pages
        ));
    }
    Ok(())
}

fn random_op(rng: &mut Pcg32, page_bytes: usize) -> Op {
    // Small len/fill alphabets so intern content actually collides.
    let lens = [0, 1, page_bytes / 2, page_bytes - 1, page_bytes, page_bytes + 3, 3 * page_bytes];
    let len = lens[rng.below(lens.len())];
    match rng.below(12) {
        0 | 1 => Op::Alloc { len, fill: rng.below(4) as u8 },
        2..=4 => Op::Intern { ns: rng.below(2) as u8, len, fill: rng.below(4) as u8 },
        5 => Op::CloneOf { pick: rng.below(1 << 16) },
        6 | 7 => Op::Write { pick: rng.below(1 << 16), pos: rng.below(1 << 16), val: rng.below(7) as u8 },
        8 => Op::MakeShared { pick: rng.below(1 << 16), ns: rng.below(2) as u8 },
        9 | 10 => Op::Free { pick: rng.below(1 << 16) },
        _ => Op::Purge,
    }
}

fn fuzz_case(rng: &mut Pcg32) {
    let budget = [0u64, 2, 3, 5, 9][rng.below(5)];
    let page_bytes = 64;
    let n_ops = 60 + rng.below(140);
    let ops: Vec<Op> = (0..n_ops).map(|_| random_op(rng, page_bytes)).collect();
    if run_ops(&ops, budget, page_bytes).is_err() {
        // Shrink by prefix replay: ops interpret slot picks modulo the
        // live slot count, so every prefix replays deterministically.
        let n = (1..=ops.len())
            .find(|&k| run_ops(&ops[..k], budget, page_bytes).is_err())
            .expect("full sequence failed, some prefix must fail");
        let err = run_ops(&ops[..n], budget, page_bytes).unwrap_err();
        panic!(
            "page-pool property failed (budget {budget} pages, shrunk to {n} ops):\n  {err}\n  ops: {:?}",
            &ops[..n]
        );
    }
}

#[test]
fn pool_matches_reference_allocator_under_fuzz() {
    // CI raises the iteration count via FO_PAGE_POOL_CASES.
    let cases = std::env::var("FO_PAGE_POOL_CASES")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(24);
    prop_check("page pool vs naive reference allocator", cases, fuzz_case);
}

// ---------------------------------------------------------------- (b) --

fn tiny_model(layers: usize, seed: u64) -> MiniMMDiT {
    let cfg = ModelConfig {
        dim: 32,
        heads: 2,
        layers,
        text_tokens: 8,
        patch_h: 4,
        patch_w: 4,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 2,
        vocab: 256,
    };
    MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, seed))
}

fn fo_policy(interval: usize, warmup: usize) -> Policy {
    Policy::flashomni(SparsityConfig {
        tau_q: 0.6,
        tau_kv: 0.3,
        interval,
        order: 1,
        s_q: 0.0,
        block_q: 8,
        block_k: 8,
        pool: 1,
        warmup,
        ramp_steps: 1,
    })
}

fn request(id: u64, scene: usize, seed: u64, steps: usize, hw: Option<(usize, usize)>) -> Request {
    Request {
        id,
        scene,
        prompt_ids: caption_ids(scene, 8),
        seed,
        steps,
        arrival_s: 0.0,
        patch_hw: hw,
    }
}

/// Solo reference at the request's own resolution on an explicit pool.
fn solo_at(model: &MiniMMDiT, policy: &Policy, req: &Request, mem: &PagePool) -> (Tensor, RunStats) {
    let mut cfg = model.cfg.clone();
    if let Some((ph, pw)) = req.patch_hw {
        cfg.patch_h = ph;
        cfg.patch_w = pw;
    }
    let m = MiniMMDiT::new(cfg, model.w.clone());
    let mut engine = DiTEngine::new(m, policy.clone(), 8, 8);
    engine.set_page_pool(mem);
    let res = engine.generate(&req.prompt_ids, req.seed, req.steps);
    (res.image, res.stats)
}

#[test]
fn solo_page_budget_is_invisible_to_numerics() {
    let model = tiny_model(2, 7);
    let policy = fo_policy(3, 1);
    let req = request(0, 4, 42, 8, None);
    let (img_free, stats_free) = solo_at(&model, &policy, &req, &PagePool::unbounded());
    let tight = PagePool::with_budget(4, 512);
    let (img_tight, stats_tight) = solo_at(&model, &policy, &req, &tight);
    assert_eq!(img_free, img_tight, "a page budget must never change the image");
    assert_eq!(stats_free.mem_pages_evicted, 0, "an unbounded pool never evicts");
    assert!(stats_tight.mem_pages_evicted > 0, "a 4-page budget must actually evict");
    assert!(stats_tight.mem_pages_allocated > 0);
    assert!(stats_tight.mem_peak_pages > 0);
    // The soft-budget bound: resident never exceeds max(budget, live).
    let s = tight.stats();
    assert!(
        s.peak_resident_pages <= s.peak_live_pages.max(tight.budget_pages()),
        "retained pages must stay under the budget: {s:?}"
    );
}

#[test]
fn tight_budget_batch_is_bitwise_identical_and_shares_prefixes() {
    // A symbol-identical pair (same prompt + seed: the repeated-prompt
    // burst) plus a distinct request at another resolution, all under a
    // tight page budget on a private pool.
    let model = tiny_model(2, 11);
    let policy = fo_policy(3, 2);
    let reqs =
        vec![request(0, 3, 100, 9, None), request(1, 3, 100, 9, None), request(2, 5, 101, 9, Some((6, 4)))];
    let tight = PagePool::with_budget(8, 1024);
    let mut engine = BatchedEngine::new(model.clone(), policy.clone(), 8, 8, reqs.len());
    engine.set_page_pool(&tight);
    for r in &reqs {
        assert!(engine.can_admit());
        engine.admit(r.clone(), Instant::now());
    }
    let mut out = engine.run_to_completion();
    out.sort_by_key(|r| r.id);
    assert_eq!(out.len(), reqs.len());

    // Bitwise identity against unbudgeted solo runs at each resolution.
    for (b, req) in out.iter().zip(&reqs) {
        let (img, _) = solo_at(&model, &policy, req, &PagePool::unbounded());
        assert_eq!(
            b.image, img,
            "request {} (patch {:?}) under budget differs from unbudgeted solo",
            b.id, req.patch_hw
        );
    }
    // The identical pair stays identical — and shared one physical copy
    // of its resident state while in flight (refcount reached the pair).
    assert_eq!(out[0].image, out[1].image);
    let pool_stats = tight.stats();
    assert!(pool_stats.peak_block_refs >= 2, "identical pair must share blocks: {pool_stats:?}");

    // Real pressure and real sharing showed up in the per-request stats.
    assert!(out[0].stats.mem_pages_evicted > 0, "tight budget must evict: {:?}", out[0].stats);
    assert!(out[0].stats.mem_share_hits > 0, "identical pair must share: {:?}", out[0].stats);
    assert!(out[0].stats.mem_pages_allocated > 0);
    assert!(out[0].stats.mem_peak_pages > 0);
    assert!(
        pool_stats.peak_resident_pages <= pool_stats.peak_live_pages.max(tight.budget_pages()),
        "retained pages must stay under the budget: {pool_stats:?}"
    );

    // No leaks: retiring every request and dropping the engine (which
    // holds the plan cache's interned keys) drains the pool to zero.
    drop(out);
    drop(engine);
    tight.purge();
    let s = tight.stats();
    assert_eq!((s.live_pages, s.resident_pages), (0, 0), "pool must drain to zero: {s:?}");
}

// ---------------------------------------------------------------- (c) --

#[test]
fn shared_batch_refresh_interns_symbol_key_once() {
    // Four lanes of one epoch look up the same packed symbol key: one
    // compile, one physical key allocation; everyone else refcounts it.
    let pool = PagePool::unbounded();
    let cache: SharedPlanCache<u32> = SharedPlanCache::new_in(8, &pool);
    let key = vec![0xabu8; 300]; // realistically-sized packed symbol key
    let epoch = cache.begin_epoch();
    let mut kept = Vec::new();
    for lane in 0..4u64 {
        let (v, _) = cache.get_or_build_keyed(&key, epoch, lane, |pk| {
            kept.push(pk.clone());
            Compiled::Full(7)
        });
        assert_eq!(*v, 7);
    }
    assert_eq!(kept.len(), 1, "the build closure must run once for the whole batch");
    assert_eq!(
        pool.stats().blocks_allocated,
        1,
        "a shared-batch refresh must allocate the key bytes exactly once"
    );
    // Map key + FIFO entry + the caller's retained copy: one block.
    assert_eq!(kept[0].ref_count(), 3);
    assert_eq!(cache.stats().shared_hits, 3);
}
