//! Integration tests for the batched generation subsystem
//! (`rust/src/batch/`): bitwise equivalence of batched vs solo execution,
//! the one-compile-per-(layer, refresh)-per-batch invariant, refresh-
//! boundary admission, FIFO token-budget packing, and `PlanCache`
//! exactness under concurrent batched access. Ragged (mixed-resolution)
//! coverage lives in `rust/tests/ragged_batching.rs`.

use flashomni::batch::{BatchScheduler, BatchedEngine};
use flashomni::config::{ModelConfig, SparsityConfig};
use flashomni::diffusion::plan_steps;
use flashomni::engine::{DiTEngine, Policy, RunStats};
use flashomni::exec::ExecPool;
use flashomni::model::{weights::Weights, MiniMMDiT};
use flashomni::plan::cache::{CacheOutcome, SharedPlanCache};
use flashomni::workload::{caption_ids, Request};
use std::time::Instant;

fn tiny_model(layers: usize, seed: u64) -> MiniMMDiT {
    let cfg = ModelConfig {
        dim: 32,
        heads: 2,
        layers,
        text_tokens: 8,
        patch_h: 4,
        patch_w: 4,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 2,
        vocab: 256,
    };
    MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, seed))
}

fn fo_policy(interval: usize, warmup: usize) -> Policy {
    Policy::flashomni(SparsityConfig {
        tau_q: 0.6,
        tau_kv: 0.3,
        interval,
        order: 1,
        s_q: 0.0,
        block_q: 8,
        block_k: 8,
        pool: 1,
        warmup,
        ramp_steps: 1,
    })
}

fn request(id: u64, scene: usize, seed: u64, steps: usize, text_tokens: usize) -> Request {
    Request {
        id,
        scene,
        prompt_ids: caption_ids(scene, text_tokens),
        seed,
        steps,
        arrival_s: 0.0,
        patch_hw: None,
    }
}

/// Solo reference: run each request through a fresh single-request engine.
fn solo_runs(
    model: &MiniMMDiT,
    policy: &Policy,
    reqs: &[Request],
) -> Vec<(flashomni::tensor::Tensor, RunStats)> {
    reqs.iter()
        .map(|r| {
            let mut engine = DiTEngine::new(model.clone(), policy.clone(), 8, 8);
            let res = engine.generate(&r.prompt_ids, r.seed, r.steps);
            (res.image, res.stats)
        })
        .collect()
}

/// Run requests through one batched engine (all admitted up front) and
/// return results sorted by request id.
fn batched_run(
    model: &MiniMMDiT,
    policy: &Policy,
    reqs: &[Request],
) -> (Vec<flashomni::batch::BatchResult>, BatchedEngine) {
    let mut engine = BatchedEngine::new(model.clone(), policy.clone(), 8, 8, reqs.len());
    for r in reqs {
        assert!(engine.can_admit());
        engine.admit(r.clone(), Instant::now());
    }
    let mut out = engine.run_to_completion();
    out.sort_by_key(|r| r.id);
    (out, engine)
}

fn assert_same_compute(batched: &RunStats, solo: &RunStats) {
    assert_eq!(batched.attn_computed_pairs, solo.attn_computed_pairs);
    assert_eq!(batched.attn_total_pairs, solo.attn_total_pairs);
    assert_eq!(batched.gq_computed, solo.gq_computed);
    assert_eq!(batched.gq_total, solo.gq_total);
    assert_eq!(batched.go_computed, solo.go_computed);
    assert_eq!(batched.go_total, solo.go_total);
    assert_eq!(batched.cached_layer_steps, solo.cached_layer_steps);
    assert_eq!(batched.total_layer_steps, solo.total_layer_steps);
    assert_eq!(batched.per_step_density, solo.per_step_density);
}

#[test]
fn batched_flashomni_bitwise_equals_solo() {
    // Distinct prompts AND seeds: batch members emit different symbols, so
    // the grouped fast path, the shared cache, and the serial fallback all
    // interleave — every request must still match its solo run bit-for-bit.
    let model = tiny_model(2, 11);
    let policy = fo_policy(3, 2);
    let reqs: Vec<Request> = (0..4)
        .map(|i| request(i, 3 * i as usize + 1, 100 + i, 9, model.cfg.text_tokens))
        .collect();
    let solo = solo_runs(&model, &policy, &reqs);
    let (batched, _) = batched_run(&model, &policy, &reqs);
    assert_eq!(batched.len(), 4);
    for (b, (img, stats)) in batched.iter().zip(&solo) {
        assert_eq!(&b.image, img, "request {} image differs from solo run", b.id);
        assert_same_compute(&b.stats, stats);
    }
}

#[test]
fn batched_identical_prompts_share_and_still_match() {
    // Symbol-identical burst: maximal sharing, still bitwise-equal output.
    let model = tiny_model(2, 7);
    let policy = fo_policy(3, 1);
    let reqs: Vec<Request> =
        (0..3).map(|i| request(i, 5, 42, 7, model.cfg.text_tokens)).collect();
    let solo = solo_runs(&model, &policy, &reqs[..1]);
    let (batched, _) = batched_run(&model, &policy, &reqs);
    for b in &batched {
        assert_eq!(b.image, solo[0].0, "shared-prompt request {} differs", b.id);
    }
}

#[test]
fn batched_other_policies_bitwise_equal_solo() {
    let model = tiny_model(2, 13);
    // FORA: whole-block caching (CachedBlock path inside the batch).
    // SpargeAttn: per-step masks (always the serial fallback inside the
    // batch). Full: dense path.
    for policy in [Policy::fora(2, 1), Policy::sparge(0.2, 0.2, 1), Policy::full()] {
        let reqs: Vec<Request> = (0..3)
            .map(|i| request(i, 7 * i as usize + 2, 50 + i, 6, model.cfg.text_tokens))
            .collect();
        let solo = solo_runs(&model, &policy, &reqs);
        let (batched, _) = batched_run(&model, &policy, &reqs);
        for (b, (img, stats)) in batched.iter().zip(&solo) {
            assert_eq!(&b.image, img, "policy {} request {} differs", policy.name(), b.id);
            assert_same_compute(&b.stats, stats);
        }
    }
}

#[test]
fn one_plan_compile_per_layer_refresh_per_batch() {
    // B symbol-identical requests: every (layer, refresh) must cost
    // exactly one compile (miss), with the other B−1 requests riding it as
    // same-epoch shared hits — the fig12 invariant.
    let layers = 2;
    let steps = 10;
    let (warmup, interval) = (2, 3);
    let model = tiny_model(layers, 11);
    let policy = fo_policy(interval, warmup);
    let batch = 4u64;
    let reqs: Vec<Request> =
        (0..batch).map(|i| request(i, 9, 77, steps, model.cfg.text_tokens)).collect();
    let (batched, engine) = batched_run(&model, &policy, &reqs);

    // A FlashOmni slot refreshes symbols at every Full (Warmup/Update) step.
    let full_steps =
        plan_steps(steps, warmup.min(steps), interval).iter().filter(|k| !k.is_sparse()).count();
    let refresh_points = (layers * full_steps) as u64;
    // Sanity on the workload: a solo run compiles once per (layer,
    // refresh) with zero hits — every refresh emits distinct symbols, so
    // the sharing arithmetic below is exact.
    let solo = solo_runs(&model, &policy, &reqs[..1]).remove(0).1;
    assert_eq!(solo.plan_cache_misses, refresh_points, "degenerate workload: colliding refreshes");
    assert_eq!(solo.plan_cache_hits, 0);
    let misses: u64 = batched.iter().map(|b| b.stats.plan_cache_misses).sum();
    let hits: u64 = batched.iter().map(|b| b.stats.plan_cache_hits).sum();
    let shared: u64 = batched.iter().map(|b| b.stats.plan_cache_shared).sum();
    assert_eq!(misses, refresh_points, "exactly one compile per (layer, refresh) per batch");
    assert_eq!(misses + hits, batch * refresh_points, "one lookup per slot per refresh");
    assert_eq!(shared, (batch - 1) * refresh_points, "everyone else rides the shared compile");
    let cs = engine.plan_cache_stats();
    assert_eq!(cs.misses, refresh_points);
    assert_eq!(cs.shared_hits, shared);
}

#[test]
fn admission_only_at_refresh_boundaries() {
    let model = tiny_model(1, 5);
    let policy = fo_policy(3, 1); // kinds: W U D D U D D ...
    let steps = 8;
    let mut sched =
        BatchScheduler::new(BatchedEngine::new(model.clone(), policy.clone(), 8, 8, 4));
    sched.submit(request(0, 1, 9, steps, model.cfg.text_tokens));
    let mut done = sched.step(); // runs step 0 (Warmup)
    assert_eq!(sched.active(), 1);
    // Next step is Update (full) → boundary: a new request joins now.
    sched.submit(request(1, 2, 10, steps, model.cfg.text_tokens));
    done.extend(sched.step());
    assert_eq!(sched.active(), 2, "admitted at the Update boundary");
    // Mid-window submission must wait: the cohort's next steps are
    // Dispatch, so the request stays pending.
    sched.submit(request(2, 3, 11, steps, model.cfg.text_tokens));
    done.extend(sched.step());
    assert_eq!(sched.active(), 2, "mid-window arrival must stay pending");
    assert_eq!(sched.pending_len(), 1);
    // Drain; everyone gets served exactly once.
    done.extend(sched.run_to_completion());
    let mut ids: Vec<u64> = done.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2]);
    // Late admits are bitwise-identical to solo runs too.
    let solo = solo_runs(&model, &policy, &[request(2, 3, 11, steps, model.cfg.text_tokens)]);
    let late = done.iter().find(|r| r.id == 2).unwrap();
    assert_eq!(late.image, solo[0].0);
}

#[test]
fn scheduler_admits_mixed_step_counts_fifo() {
    // The token-budget packer replaced step-count bucketing: requests
    // with different step counts ride one batch, each retiring on its own
    // schedule without stalling the rest.
    let model = tiny_model(1, 3);
    let policy = Policy::full();
    let engine = BatchedEngine::new(model.clone(), policy.clone(), 8, 8, 4);
    let mut sched = BatchScheduler::with_token_budget(engine, 0);
    for (id, steps) in [(0u64, 4usize), (1, 4), (2, 6), (3, 4)] {
        sched.submit(request(id, id as usize, id, steps, model.cfg.text_tokens));
    }
    let _ = sched.step();
    assert_eq!(sched.active(), 4, "mixed step counts share one batch");
    assert_eq!(sched.pending_len(), 0);
    let done = sched.run_to_completion();
    let mut ids: Vec<u64> = done.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2, 3]);
    for r in &done {
        assert!(r.image.data().iter().all(|x| x.is_finite()));
        assert!(r.latency_s >= r.exec_s);
        // Each request still matches its solo run despite the mixed batch.
        let solo = solo_runs(
            &model,
            &policy,
            &[request(r.id, r.id as usize, r.id, r.stats.steps, model.cfg.text_tokens)],
        );
        assert_eq!(r.image, solo[0].0, "request {} differs from solo", r.id);
    }
}

#[test]
fn zero_step_requests_are_served() {
    // A steps == 0 request must retire immediately with the initial-noise
    // image (solo `generate(steps=0)` semantics) instead of panicking the
    // engine, and must not wedge the scheduler or later cohorts.
    let model = tiny_model(1, 3);
    let policy = Policy::full();
    let mut sched =
        BatchScheduler::new(BatchedEngine::new(model.clone(), policy.clone(), 8, 8, 2));
    sched.submit(request(0, 1, 5, 0, model.cfg.text_tokens));
    sched.submit(request(1, 2, 6, 3, model.cfg.text_tokens));
    let done = sched.run_to_completion();
    assert!(sched.is_idle());
    let mut ids: Vec<u64> = done.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1]);
    let solo = solo_runs(&model, &policy, &[request(0, 1, 5, 0, model.cfg.text_tokens)]);
    let zero = done.iter().find(|r| r.id == 0).unwrap();
    assert_eq!(zero.image, solo[0].0, "zero-step image must be the initial noise");
    assert_eq!(zero.stats.steps, 0);
}

#[test]
fn plan_cache_counters_exact_under_pool_contention() {
    // Hammer one SharedPlanCache from several threads whose compile
    // closures themselves run parallel sections on the global ExecPool
    // (the situation inside a batched engine under load). Counter
    // invariants must hold exactly.
    let cache: SharedPlanCache<Vec<usize>> = SharedPlanCache::new(8);
    let threads = 4;
    let lookups_per_thread = 200;
    let key_space = 16u8;
    std::thread::scope(|scope| {
        for t in 0..threads {
            let cache = cache.clone();
            scope.spawn(move || {
                let pool = ExecPool::global();
                for i in 0..lookups_per_thread {
                    let key = [((i + t * 7) % key_space as usize) as u8];
                    let (v, _) = cache.get_or_compile(&key, || {
                        // Simulated plan compile doing pool work.
                        pool.parallel_map_indexed(8, |j| j * (key[0] as usize + 1))
                    });
                    assert_eq!(v[3], 3 * (key[0] as usize + 1));
                }
            });
        }
    });
    let s = cache.stats();
    assert_eq!(
        s.hits + s.misses,
        (threads * lookups_per_thread) as u64,
        "every lookup is exactly one hit or one miss"
    );
    // Every miss inserted an entry; inserts − evictions = current size.
    assert_eq!(s.misses - s.evictions, cache.len() as u64);
    assert!(cache.len() <= 8);
    assert!(s.misses >= key_space as u64, "each key must compile at least once");
    assert_eq!(s.shared_hits, 0, "no epochs opened → no shared hits");
}

#[test]
fn shared_cache_eviction_is_fifo() {
    let cache: SharedPlanCache<u8> = SharedPlanCache::new(2);
    cache.get_or_compile(&[0], || 0);
    cache.get_or_compile(&[1], || 1);
    cache.get_or_compile(&[2], || 2); // evicts key 0 (FIFO)
    assert_eq!(cache.stats().evictions, 1);
    let (_, o) = cache.get_or_compile(&[1], || unreachable!("1 must survive"));
    assert_eq!(o, CacheOutcome::Hit);
    let (_, o) = cache.get_or_compile(&[2], || unreachable!("2 must survive"));
    assert_eq!(o, CacheOutcome::Hit);
    let (_, o) = cache.get_or_compile(&[0], || 0);
    assert_eq!(o, CacheOutcome::Miss, "FIFO-evicted key must recompile");
}

#[test]
fn cross_engine_plan_sharing_via_shared_cache() {
    // Two batched engines (two "workers") sharing one cache: the second
    // engine's identical request hits on every refresh and compiles
    // nothing — cross-worker plan sharing.
    let model = tiny_model(2, 11);
    let policy = fo_policy(3, 1);
    let cache: SharedPlanCache<flashomni::engine::LayerPlans> = SharedPlanCache::new(64);
    let req = request(0, 4, 21, 7, model.cfg.text_tokens);

    let mut e1 = BatchedEngine::new(model.clone(), policy.clone(), 8, 8, 1);
    e1.set_plan_cache(cache.clone());
    e1.admit(req.clone(), Instant::now());
    let r1 = e1.run_to_completion().remove(0);
    assert!(r1.stats.plan_cache_misses > 0);

    let mut e2 = BatchedEngine::new(model.clone(), policy.clone(), 8, 8, 1);
    e2.set_plan_cache(cache.clone());
    let mut req2 = req.clone();
    req2.id = 1;
    e2.admit(req2, Instant::now());
    let r2 = e2.run_to_completion().remove(0);
    assert_eq!(r2.stats.plan_cache_misses, 0, "second worker must reuse every plan");
    assert!(r2.stats.plan_cache_hits > 0);
    assert_eq!(r1.image, r2.image);
}
