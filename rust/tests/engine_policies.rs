//! End-to-end policy behaviour on the *trained* model: quality ordering,
//! sparsity accounting, degradation, determinism, serving.

use flashomni::config::SparsityConfig;
use flashomni::engine::{DiTEngine, Policy};
use flashomni::metrics;
use flashomni::model::MiniMMDiT;
use flashomni::workload::caption_ids;

fn load_model() -> Option<MiniMMDiT> {
    for dir in ["artifacts", "../artifacts"] {
        let p = format!("{dir}/weights.fot");
        if std::path::Path::new(&p).exists() {
            return Some(MiniMMDiT::load(&p).unwrap());
        }
    }
    eprintln!("SKIP: weights.fot not found — run `make artifacts`");
    None
}

const STEPS: usize = 12;

fn gen(model: &MiniMMDiT, policy: Policy, seed: u64) -> (flashomni::tensor::Tensor, f64, f64) {
    let mut e = DiTEngine::new(model.clone(), policy, 8, 8);
    let ids = caption_ids(3, model.cfg.text_tokens);
    let r = e.generate(&ids, seed, STEPS);
    (r.image, r.stats.attn_sparsity(), r.stats.flop_speedup())
}

#[test]
fn trained_model_zero_tau_matches_dense() {
    let Some(model) = load_model() else { return };
    let (dense, s0, _) = gen(&model, Policy::full(), 5);
    let cfg = SparsityConfig {
        warmup: 1,
        ramp_steps: 1,
        ..SparsityConfig::paper(0.0, 0.0, 3, 1, 0.0)
    };
    let (sparse0, s1, _) = gen(&model, Policy::flashomni(cfg), 5);
    assert_eq!(s0, 0.0);
    assert_eq!(s1, 0.0);
    let psnr = metrics::psnr(&sparse0, &dense);
    assert!(psnr > 40.0, "zero-sparsity run deviates from dense: PSNR {psnr}");
}

#[test]
fn quality_orderings_match_paper() {
    // The paper's headline quality claims, on our substrate:
    //  1. FlashOmni(D=1) ≥ FORA at equal interval (forecast beats reuse).
    //  2. Higher interval N degrades quality (Table 3 trend).
    let Some(model) = load_model() else { return };
    let (dense, ..) = gen(&model, Policy::full(), 5);

    let (fo, fo_sp, _) = gen(
        &model,
        Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 4, 1, 0.0)),
        5,
    );
    let (fora, ..) = gen(&model, Policy::fora(4, 4), 5);
    let psnr_fo = metrics::psnr(&fo, &dense);
    let psnr_fora = metrics::psnr(&fora, &dense);
    assert!(fo_sp > 0.0, "FlashOmni must actually skip");
    assert!(
        psnr_fo > psnr_fora - 0.5,
        "FlashOmni ({psnr_fo:.2}dB) should not lose clearly to FORA ({psnr_fora:.2}dB)"
    );

    // 3. Larger interval N ⇒ more work amortized away (sparsity up), and
    //    quality stays usable (the precise Table-3 PSNR trend needs the
    //    full reproduce harness's multi-scene averaging; at one scene and
    //    12 steps it is noise-dominated).
    // (ramp_steps = 1 so the per-update τ is constant and the comparison
    // isolates the interval N rather than the A.1.1 threshold ramp.)
    let mk = |n: usize| {
        Policy::flashomni(SparsityConfig {
            warmup: 2,
            ramp_steps: 1,
            ..SparsityConfig::paper(0.5, 0.15, n, 1, 0.0)
        })
    };
    let (n3, sp3, _) = gen(&model, mk(3), 5);
    let (n7, sp7, _) = gen(&model, mk(7), 5);
    assert!(sp7 >= sp3 - 0.02, "sparsity should grow with N: {sp3} vs {sp7}");
    assert!(metrics::psnr(&n3, &dense) > 20.0);
    assert!(metrics::psnr(&n7, &dense) > 20.0);

    // 4. First-order forecast beats direct reuse at the same config
    //    (Table 3's D ablation), with a small noise margin.
    let (d0, ..) = gen(
        &model,
        Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 5, 0, 0.0)),
        5,
    );
    let (d1, ..) = gen(
        &model,
        Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 5, 1, 0.0)),
        5,
    );
    let p_d0 = metrics::psnr(&d0, &dense);
    let p_d1 = metrics::psnr(&d1, &dense);
    assert!(
        p_d1 > p_d0 - 4.0,
        "D=1 ({p_d1:.2}dB) collapsed vs D=0 ({p_d0:.2}dB); fine-grained ordering is established by the Table 3 harness"
    );
}

#[test]
fn degradation_threshold_kicks_in() {
    let Some(model) = load_model() else { return };
    // With an extreme S_q = 0.95 almost every layer degenerates to full
    // caching on dispatch steps.
    let cfg = SparsityConfig {
        warmup: 2,
        ramp_steps: 1,
        ..SparsityConfig::paper(0.5, 0.15, 4, 1, 0.95)
    };
    let mut e = DiTEngine::new(model.clone(), Policy::flashomni(cfg), 8, 8);
    let ids = caption_ids(3, model.cfg.text_tokens);
    let r = e.generate(&ids, 5, STEPS);
    assert!(
        r.stats.cached_layer_steps > 0,
        "S_q=0.95 should degrade layers to full caching"
    );
    assert!(r.image.data().iter().all(|x| x.is_finite()));
}

#[test]
fn sparge_and_dfa2_never_cache() {
    let Some(model) = load_model() else { return };
    for policy in [Policy::sparge(0.1, 0.1, 2), Policy::dfa2(0.3, 2)] {
        let name = policy.name();
        let mut e = DiTEngine::new(model.clone(), policy, 8, 8);
        let ids = caption_ids(3, model.cfg.text_tokens);
        let r = e.generate(&ids, 5, STEPS);
        assert_eq!(r.stats.cached_layer_steps, 0, "{name} must not block-cache");
        assert_eq!(
            r.stats.gq_computed, r.stats.gq_total,
            "{name} must not skip GEMM-Q tiles"
        );
        assert!(
            r.stats.attn_computed_pairs < r.stats.attn_total_pairs,
            "{name} must skip attention pairs"
        );
    }
}

#[test]
fn generation_is_deterministic_per_seed_and_policy() {
    let Some(model) = load_model() else { return };
    let p = || Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 4, 1, 0.3));
    let (a, ..) = gen(&model, p(), 9);
    let (b, ..) = gen(&model, p(), 9);
    assert_eq!(a, b);
    let (c, ..) = gen(&model, p(), 10);
    assert!(a.max_abs_diff(&c) > 0.0);
}

#[test]
fn engine_reset_isolates_requests() {
    let Some(model) = load_model() else { return };
    let policy = Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 4, 1, 0.3));
    // Same engine, two generations — second must equal a fresh engine's.
    let mut e = DiTEngine::new(model.clone(), policy.clone(), 8, 8);
    let ids = caption_ids(3, model.cfg.text_tokens);
    let _ = e.generate(&ids, 1, STEPS);
    let r2 = e.generate(&ids, 2, STEPS);
    let mut fresh = DiTEngine::new(model.clone(), policy, 8, 8);
    let rf = fresh.generate(&ids, 2, STEPS);
    assert_eq!(r2.image, rf.image, "engine state leaked across requests");
}

#[test]
fn pooled_symbols_run_and_shrink_storage() {
    // §3.3 n-pooling: pool=2 halves the symbol bits per axis while the
    // engine still produces a valid (finite, near-dense-quality) sample.
    let Some(model) = load_model() else { return };
    let mk = |pool: usize| {
        let cfg = SparsityConfig {
            warmup: 2,
            ramp_steps: 2,
            pool,
            ..SparsityConfig::paper(0.5, 0.15, 4, 1, 0.0)
        };
        DiTEngine::with_pool(model.clone(), Policy::flashomni(cfg), 8, 8, pool)
    };
    let ids = caption_ids(3, model.cfg.text_tokens);
    let (dense, ..) = gen(&model, Policy::full(), 5);
    let mut e1 = mk(1);
    let mut e2 = mk(2);
    let r1 = e1.generate(&ids, 5, STEPS);
    let r2 = e2.generate(&ids, 5, STEPS);
    assert!(r2.image.data().iter().all(|x| x.is_finite()));
    assert!(metrics::psnr(&r2.image, &dense) > 20.0);
    // Coarser decisions may change sparsity but both must actually skip.
    assert!(r1.stats.attn_sparsity() > 0.0);
    assert!(r2.stats.attn_sparsity() > 0.0);
    // Symbol storage halves per axis with pool=2.
    use flashomni::symbols::HeadSymbols;
    let s1 = HeadSymbols::dense(20, 20, 1).packed_bytes();
    let s2 = HeadSymbols::dense(20, 20, 2).packed_bytes();
    assert!(s2 < s1, "pooling must shrink packed symbols: {s1} vs {s2}");
}
