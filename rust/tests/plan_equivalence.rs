//! Plan-equivalence property tests (PR 1 acceptance):
//!
//! (a) `BitSymbols` pack/unpack round-trips on random masks,
//! (b) the plan-based kernels are **bitwise-identical** to the seed
//!     symbol-decoding kernels on random `HeadSymbols` and on symbols
//!     emitted by a real randomized policy (`flashomni_masks`),
//! (c) `RowCached` and `PerAccess` plan compilation produce identical
//!     live-index sets.

use flashomni::kernels::attention::{flashomni_attention, flashomni_attention_symbols};
use flashomni::kernels::gemm_o::{
    gemm_o_dispatch, gemm_o_dispatch_symbols, gemm_o_stage1, gemm_o_stage1_symbols,
    gemm_o_update, gemm_o_update_symbols, WeightPanels,
};
use flashomni::kernels::gemm_q::{gemm_q, gemm_q_symbols};
use flashomni::masks::flashomni_masks;
use flashomni::plan::{DecodeMode, HeadPlan, SparsePlan};
use flashomni::symbols::{BitSymbols, HeadSymbols, LayerSymbols};
use flashomni::testutil::{prop_check, rand_mask, randn};
use flashomni::util::rng::Pcg32;

fn random_layer_syms(
    rng: &mut Pcg32,
    heads: usize,
    qg: usize,
    kg: usize,
    pool: usize,
) -> LayerSymbols {
    LayerSymbols {
        heads: (0..heads)
            .map(|_| {
                let m_c = rand_mask(rng, qg, 0.6);
                let m_s = rand_mask(rng, qg * kg, 0.5);
                HeadSymbols::from_masks(&m_c, &m_s, kg, pool)
            })
            .collect(),
    }
}

// ---------------------------------------------------------------- (a) --

#[test]
fn bitsymbols_roundtrip_random_masks() {
    prop_check("BitSymbols pack/unpack roundtrip", 100, |rng| {
        let n = 1 + rng.below(200);
        let density = rng.f64();
        let bits = rand_mask(rng, n, density);
        let b = BitSymbols::from_bits(&bits);
        assert_eq!(b.len(), n);
        assert_eq!(b.to_bits(), bits, "unpack must invert pack");
        assert_eq!(b.count_ones(), bits.iter().filter(|&&x| x).count());
        // Round-trip through the raw byte representation too (.fot path).
        let b2 = BitSymbols::from_bytes(b.bytes().to_vec(), n);
        assert_eq!(b2, b);
        assert_eq!(b2.to_bits(), bits);
    });
}

// ---------------------------------------------------------------- (c) --

#[test]
fn rowcached_and_peraccess_plans_are_identical() {
    prop_check("RowCached plan == PerAccess plan", 80, |rng| {
        let pool = 1 + rng.below(3);
        let t_q = 1 + rng.below(48);
        let t_kv = 1 + rng.below(48);
        let qg = t_q.div_ceil(pool);
        let kg = t_kv.div_ceil(pool);
        let (dc, ds) = (rng.f64(), rng.f64());
        let m_c = rand_mask(rng, qg, dc);
        let m_s = rand_mask(rng, qg * kg, ds);
        let sym = HeadSymbols::from_masks(&m_c, &m_s, kg, pool);
        let a = HeadPlan::from_symbols(&sym, t_q, t_kv, DecodeMode::RowCached);
        let b = HeadPlan::from_symbols(&sym, t_q, t_kv, DecodeMode::PerAccess);
        assert_eq!(a, b, "decode modes must yield the same live-index sets");
    });
}

// ---------------------------------------------------------------- (b) --

#[test]
fn plan_attention_bitwise_matches_symbol_kernel() {
    prop_check("plan attention == symbol attention (bitwise)", 25, |rng| {
        let n = 16 + rng.below(64);
        let d = 4 + rng.below(12);
        let bq = 4 + rng.below(8);
        let bk = 4 + rng.below(8);
        let pool = 1 + rng.below(2);
        let t_q = n.div_ceil(bq);
        let t_kv = n.div_ceil(bk);
        let qg = t_q.div_ceil(pool);
        let kg = t_kv.div_ceil(pool);
        let q = randn(rng, &[n, d]);
        let k = randn(rng, &[n, d]);
        let v = randn(rng, &[n, d]);
        let cached = randn(rng, &[n, d]);
        let sym = HeadSymbols::from_masks(
            &rand_mask(rng, qg, 0.7),
            &rand_mask(rng, qg * kg, 0.6),
            kg,
            pool,
        );
        let (want, wstats) =
            flashomni_attention_symbols(&q, &k, &v, &sym, bq, bk, Some(&cached), DecodeMode::RowCached);
        let plan = HeadPlan::from_symbols(&sym, t_q, t_kv, DecodeMode::RowCached);
        let (got, gstats) = flashomni_attention(&q, &k, &v, &plan, bq, bk, Some(&cached));
        assert_eq!(got.data(), want.data(), "attention outputs must be bitwise equal");
        assert_eq!(gstats.computed_pairs, wstats.computed_pairs);
        assert_eq!(gstats.total_pairs, wstats.total_pairs);
        assert_eq!(gstats.cached_blocks, wstats.cached_blocks);
        // Bias-optimized path (no cached_o) as well.
        let (want2, _) =
            flashomni_attention_symbols(&q, &k, &v, &sym, bq, bk, None, DecodeMode::PerAccess);
        let (got2, _) = flashomni_attention(&q, &k, &v, &plan, bq, bk, None);
        assert_eq!(got2.data(), want2.data());
    });
}

#[test]
fn plan_gemm_q_bitwise_matches_symbol_kernel() {
    prop_check("plan GEMM-Q == symbol GEMM-Q (bitwise)", 25, |rng| {
        let n = 16 + rng.below(48);
        let d_in = 4 + rng.below(12);
        let heads = 1 + rng.below(4);
        let d_h = 2 + rng.below(6);
        let b = 4 + rng.below(8);
        let t_q = n.div_ceil(b);
        let x = randn(rng, &[n, d_in]);
        let w = randn(rng, &[d_in, heads * d_h]);
        let bias: Vec<f32> = (0..heads * d_h).map(|_| rng.normal()).collect();
        let syms = random_layer_syms(rng, heads, t_q, t_q, 1);
        let plan = SparsePlan::compile(&syms, t_q, t_q, b, b, DecodeMode::RowCached);
        for bias_opt in [None, Some(&bias[..])] {
            let (want, wstats) = gemm_q_symbols(&x, &w, &syms, b, bias_opt);
            let (got, gstats) = gemm_q(&x, &w, &plan, bias_opt);
            assert_eq!(got.data(), want.data(), "GEMM-Q outputs must be bitwise equal");
            assert_eq!(gstats.computed_tiles, wstats.computed_tiles);
            assert_eq!(gstats.total_tiles, wstats.total_tiles);
        }
    });
}

#[test]
fn plan_gemm_o_bitwise_matches_symbol_kernels() {
    prop_check("plan GEMM-O == symbol GEMM-O (bitwise)", 25, |rng| {
        let n = 16 + rng.below(48);
        let heads = 1 + rng.below(4);
        let d_h = 2 + rng.below(6);
        let d_out = 4 + rng.below(12);
        let b = 4 + rng.below(8);
        let t_q = n.div_ceil(b);
        let o = randn(rng, &[n, heads * d_h]);
        let w = randn(rng, &[heads * d_h, d_out]);
        let panels = WeightPanels::new(&w, heads);
        let syms = random_layer_syms(rng, heads, t_q, t_q, 1);
        let plan = SparsePlan::compile(&syms, t_q, t_q, b, b, DecodeMode::RowCached);

        let (want_out, want_bias, wstats) = gemm_o_update_symbols(&o, &panels, &syms, b);
        let (got_out, got_bias, gstats) = gemm_o_update(&o, &panels, &plan);
        assert_eq!(got_out.data(), want_out.data(), "update outputs must be bitwise equal");
        assert_eq!(got_bias.data(), want_bias.data(), "update biases must be bitwise equal");
        assert_eq!(gstats.computed_tiles, wstats.computed_tiles);

        let want_s1 = gemm_o_stage1_symbols(&o, &panels, &syms, b);
        let got_s1 = gemm_o_stage1(&o, &panels, &plan);
        assert_eq!(got_s1.data(), want_s1.data(), "stage-1 biases must be bitwise equal");

        let (want_d, wd) = gemm_o_dispatch_symbols(&o, &panels, &syms, b, &want_bias);
        let (got_d, gd) = gemm_o_dispatch(&o, &panels, &plan, &got_bias);
        assert_eq!(got_d.data(), want_d.data(), "dispatch outputs must be bitwise equal");
        assert_eq!(gd.computed_tiles, wd.computed_tiles);
    });
}

#[test]
fn plan_kernels_match_on_randomized_policy_symbols() {
    // Symbols emitted by the actual FlashOmni mask policy (Eq. 1 + BSS
    // selection on random Q/K), not just uniform random masks.
    prop_check("plan == symbols on policy-emitted masks", 15, |rng| {
        let b = 8;
        let n = 64 + 8 * rng.below(8); // multiple of 8
        let d = 8 + rng.below(16);
        let t = n / b;
        let q = randn(rng, &[n, d]);
        let k = randn(rng, &[n, d]);
        let v = randn(rng, &[n, d]);
        let tau_q = 0.2 + 0.6 * rng.f64();
        let tau_kv = 0.1 + 0.4 * rng.f64();
        let m = flashomni_masks(&q, &k, b, b, 8, tau_q, tau_kv);
        let sym = HeadSymbols::from_masks(&m.m_c, &m.m_s, m.kv_groups, 1);
        let plan = HeadPlan::from_symbols(&sym, t, t, DecodeMode::RowCached);
        let (want, wstats) =
            flashomni_attention_symbols(&q, &k, &v, &sym, b, b, None, DecodeMode::RowCached);
        let (got, gstats) = flashomni_attention(&q, &k, &v, &plan, b, b, None);
        assert_eq!(got.data(), want.data());
        assert_eq!(gstats.computed_pairs, wstats.computed_pairs);

        let syms = LayerSymbols { heads: vec![sym] };
        let lplan = SparsePlan::compile(&syms, t, t, b, b, DecodeMode::RowCached);
        let x = randn(rng, &[n, d]);
        let wq = randn(rng, &[d, d]);
        let (want_q, _) = gemm_q_symbols(&x, &wq, &syms, b, None);
        let (got_q, _) = gemm_q(&x, &wq, &lplan, None);
        assert_eq!(got_q.data(), want_q.data());
    });
}

#[test]
fn sliced_plans_partition_the_joint_plan() {
    // The engine slices the joint plan at the text/vision boundary; the
    // slices must exactly partition live tiles and pairs.
    prop_check("plan slices partition", 40, |rng| {
        let heads = 1 + rng.below(4);
        let t_q = 2 + rng.below(30);
        let t_kv = 1 + rng.below(30);
        let split = rng.below(t_q + 1);
        let syms = random_layer_syms(rng, heads, t_q, t_kv, 1);
        let plan = SparsePlan::compile(&syms, t_q, t_kv, 8, 8, DecodeMode::RowCached);
        let head = plan.slice_q(0, split);
        let tail = plan.slice_q(split, t_q);
        let g = plan.gemm_stats();
        let gh = head.gemm_stats();
        let gt = tail.gemm_stats();
        assert_eq!(gh.computed_tiles + gt.computed_tiles, g.computed_tiles);
        assert_eq!(gh.total_tiles + gt.total_tiles, g.total_tiles);
        let a = plan.attn_stats();
        let ah = head.attn_stats();
        let at = tail.attn_stats();
        assert_eq!(ah.computed_pairs + at.computed_pairs, a.computed_pairs);
    });
}
