//! PR 6 property tests for the SIMD microkernel layer and the tiling
//! autotuner.
//!
//! Invariants pinned here:
//!
//! * Every SIMD kernel path is **tolerance-close** to the scalar oracle
//!   (the seed kernels' exact float sequences) across odd, padded, and
//!   lane-aligned geometries. SIMD changes the reduction order (8-lane
//!   trees vs left-to-right), so these are tolerance comparisons — the
//!   tolerances (atol 1e-3, rtol 1e-4) bound the reassociation error at
//!   these sizes with unit-normal inputs.
//! * The chunk half of a [`KernelConfig`] is **bitwise-irrelevant**: the
//!   pool kernel partitions work, never float math, so any
//!   `tasks_per_thread` gives the bit-identical tensor.
//! * The autotuner's measured config and the heuristic config produce
//!   identical outputs (bitwise when they agree on ISA, tolerance-close
//!   otherwise) — tuning can never change *what* is computed.
//! * The FO_TUNE_CACHE dump/load round-trip preserves decisions.

use flashomni::exec::ExecPool;
use flashomni::kernels::attention::{attention_dense_isa, flashomni_attention_isa};
use flashomni::kernels::gemm::{matmul_into_isa, matmul_nt_into_isa};
use flashomni::kernels::gemm_o::{gemm_o_dispatch_isa, gemm_o_update_isa, WeightPanels};
use flashomni::kernels::gemm_q::{gemm_q_isa, gemm_q_pool_with};
use flashomni::kernels::microkernel::Isa;
use flashomni::kernels::tune::{self, Family, KernelConfig};
use flashomni::plan::{DecodeMode, HeadPlan, SparsePlan};
use flashomni::symbols::random_symbols;
use flashomni::tensor::Tensor;
use flashomni::testutil::{assert_close, prop_check, randn};
use flashomni::util::rng::Pcg32;

const ATOL: f32 = 1e-3;
const RTOL: f32 = 1e-4;

fn random_plan(rng: &mut Pcg32, heads: usize, t: usize, block: usize) -> SparsePlan {
    let syms = flashomni::symbols::LayerSymbols {
        heads: (0..heads).map(|_| random_symbols(rng, t, t, 1, 0.3, 0.3)).collect(),
    };
    SparsePlan::compile(&syms, t, t, block, block, DecodeMode::RowCached)
}

// ---------------------------------------------------------------- matmul

#[test]
fn matmul_simd_matches_scalar_oracle() {
    // Odd / sub-lane / lane-multiple / lane+tail inner dims all hit
    // different microkernel body-vs-tail splits.
    prop_check("matmul simd≈scalar", 12, |rng| {
        let case = rng.below(4);
        let (m, k, n) = [(3, 5, 7), (4, 8, 16), (5, 17, 9), (1, 1, 1)][case];
        let a = randn(rng, &[m, k]);
        let b = randn(rng, &[k, n]);
        let mut c_s = Tensor::zeros(&[m, n]);
        let mut c_v = Tensor::zeros(&[m, n]);
        matmul_into_isa(Isa::Scalar, a.data(), b.data(), c_s.data_mut(), m, k, n);
        matmul_into_isa(Isa::Simd, a.data(), b.data(), c_v.data_mut(), m, k, n);
        assert_close(&c_v, &c_s, ATOL, RTOL);

        // B-transposed flavor (dot microkernel).
        let bt = randn(rng, &[n, k]);
        let mut d_s = Tensor::zeros(&[m, n]);
        let mut d_v = Tensor::zeros(&[m, n]);
        matmul_nt_into_isa(Isa::Scalar, a.data(), bt.data(), d_s.data_mut(), m, k, n);
        matmul_nt_into_isa(Isa::Simd, a.data(), bt.data(), d_v.data_mut(), m, k, n);
        assert_close(&d_v, &d_s, ATOL, RTOL);
    });
}

// ---------------------------------------------------------------- gemm_q

#[test]
fn gemm_q_simd_matches_scalar_across_odd_geometries() {
    // d_h = 7 (sub-lane, padded to 8), 20 (lane + tail, padded to 24) and
    // 16 (lane-aligned, padding is a no-op) exercise the gemm_q panel
    // padding shim; n = 50 with block 16 leaves a 2-row tail tile.
    for (heads, d_h) in [(3usize, 7usize), (2, 20), (2, 16)] {
        prop_check(&format!("gemm_q simd≈scalar d_h={d_h}"), 4, |rng| {
            let (n, block) = (50, 16);
            let t = n_div_ceil(n, block);
            let d_in = 24;
            let x = randn(rng, &[n, d_in]);
            let w = randn(rng, &[d_in, heads * d_h]);
            let plan = random_plan(rng, heads, t, block);
            let bias: Vec<f32> = randn(rng, &[1, heads * d_h]).data().to_vec();
            let (y_s, _) = gemm_q_isa(Isa::Scalar, &x, &w, &plan, Some(&bias));
            let (y_v, _) = gemm_q_isa(Isa::Simd, &x, &w, &plan, Some(&bias));
            assert_close(&y_v, &y_s, ATOL, RTOL);
        });
    }
}

// ------------------------------------------------------------- attention

#[test]
fn attention_simd_matches_scalar() {
    // Odd d (no full lane), d = 8 (exactly one lane), d = 20 (lane+tail);
    // n = 40 with block 16 leaves a ragged tail block.
    for d in [5usize, 8, 20] {
        prop_check(&format!("attention simd≈scalar d={d}"), 4, |rng| {
            let (n, block) = (40, 16);
            let t = n_div_ceil(n, block);
            let q = randn(rng, &[n, d]);
            let k = randn(rng, &[n, d]);
            let v = randn(rng, &[n, d]);
            let dense_s = attention_dense_isa(Isa::Scalar, &q, &k, &v, block, block);
            let dense_v = attention_dense_isa(Isa::Simd, &q, &k, &v, block, block);
            assert_close(&dense_v, &dense_s, ATOL, RTOL);

            let sym = random_symbols(rng, t, t, 1, 0.3, 0.3);
            let plan = HeadPlan::from_symbols(&sym, t, t, DecodeMode::RowCached);
            let (o_s, _) = flashomni_attention_isa(Isa::Scalar, &q, &k, &v, &plan, block, block, None);
            let (o_v, _) = flashomni_attention_isa(Isa::Simd, &q, &k, &v, &plan, block, block, None);
            assert_close(&o_v, &o_s, ATOL, RTOL);
        });
    }
}

// ---------------------------------------------------------------- gemm_o

#[test]
fn gemm_o_simd_matches_scalar() {
    // d_h = 20 (lane + tail) and d_out = heads·d_h = 60: GEMM-O is NOT
    // lane-padded (it accumulates in place into d_out-strided rows), so
    // this pins the unpadded SIMD path.
    prop_check("gemm_o simd≈scalar", 4, |rng| {
        let (heads, d_h, n, block) = (3usize, 20usize, 50usize, 16usize);
        let t = n_div_ceil(n, block);
        let d = heads * d_h;
        let o = randn(rng, &[n, d]);
        let w = randn(rng, &[d, d]);
        let panels = WeightPanels::new(&w, heads);
        let plan = random_plan(rng, heads, t, block);
        let (y_s, b_s, _) = gemm_o_update_isa(Isa::Scalar, &o, &panels, &plan);
        let (y_v, b_v, _) = gemm_o_update_isa(Isa::Simd, &o, &panels, &plan);
        assert_close(&y_v, &y_s, ATOL, RTOL);
        assert_close(&b_v, &b_s, ATOL, RTOL);
        let (z_s, _) = gemm_o_dispatch_isa(Isa::Scalar, &o, &panels, &plan, &b_s);
        let (z_v, _) = gemm_o_dispatch_isa(Isa::Simd, &o, &panels, &plan, &b_s);
        assert_close(&z_v, &z_s, ATOL, RTOL);
    });
}

// ------------------------------------------------- config ⟂ float output

#[test]
fn chunk_config_never_changes_bits() {
    // The tasks_per_thread half of a KernelConfig only partitions the tile
    // loop; for a fixed ISA every partition must give the bit-identical
    // tensor (and match the serial kernel).
    let pool = ExecPool::new(3);
    let mut rng = Pcg32::seeded(0xc0f9);
    let (heads, d_h, n, block) = (2usize, 16usize, 64usize, 16usize);
    let t = n_div_ceil(n, block);
    let d_in = 32;
    let x = randn(&mut rng, &[n, d_in]);
    let w = randn(&mut rng, &[d_in, heads * d_h]);
    let plan = random_plan(&mut rng, heads, t, block);
    for isa in [Isa::Scalar, Isa::Simd] {
        let (serial, _) = gemm_q_isa(isa, &x, &w, &plan, None);
        for tpt in [1usize, 2, 7, 100] {
            let cfg = KernelConfig { isa, tasks_per_thread: tpt };
            let (pooled, _) = gemm_q_pool_with(&x, &w, &plan, None, &pool, Some(cfg));
            assert_eq!(
                pooled.data(),
                serial.data(),
                "pool output must be bitwise-identical to serial (isa {isa:?}, tpt {tpt})"
            );
        }
    }
}

#[test]
fn tuned_config_matches_heuristic_output() {
    // Regression for the autotuner: whatever config `tune_now` measures
    // for a geometry, running the kernel under it computes the same thing
    // as the heuristic config — bitwise when the ISA agrees, within the
    // scalar-oracle tolerance when tuning flipped the ISA.
    let pool = ExecPool::new(2);
    let mut rng = Pcg32::seeded(0x7a9e);
    let (heads, d_h, n, block) = (2usize, 8usize, 32usize, 16usize);
    let t = n_div_ceil(n, block);
    let d_in = 16;
    let x = randn(&mut rng, &[n, d_in]);
    let w = randn(&mut rng, &[d_in, heads * d_h]);
    let plan = random_plan(&mut rng, heads, t, block);
    let tuned = tune::tune_now(Family::GemmQ, [block, d_in, d_h], pool.size());
    let heuristic = KernelConfig::heuristic();
    let (y_t, _) = gemm_q_pool_with(&x, &w, &plan, None, &pool, Some(tuned));
    let (y_h, _) = gemm_q_pool_with(&x, &w, &plan, None, &pool, Some(heuristic));
    if tuned.isa == heuristic.isa {
        assert_eq!(y_t.data(), y_h.data(), "same ISA ⇒ bitwise-identical");
    } else {
        assert_close(&y_t, &y_h, ATOL, RTOL);
    }
}

// ------------------------------------------------------------ tune cache

#[test]
fn tune_cache_roundtrip_preserves_decisions() {
    // Populate the table via the enabled config_for path, dump, reload.
    tune::set_enabled(true);
    let before = tune::config_for(Family::Attention, [16, 8, 16], 1);
    let path = std::env::temp_dir().join("flashomni_simd_tune_cache_test.txt");
    let p = path.to_str().unwrap();
    tune::dump(p).expect("dump must succeed");
    let n = tune::load(p).expect("load must succeed");
    assert!(n >= 1, "dump/load must round-trip at least the entry we created");
    // A second resolve hits the (re)loaded table and returns the same pick.
    let after = tune::config_for(Family::Attention, [16, 8, 16], 1);
    assert_eq!(before.isa, after.isa, "cache round-trip must preserve the ISA decision");
    tune::set_enabled(false);
    let _ = std::fs::remove_file(p);
}

fn n_div_ceil(n: usize, d: usize) -> usize {
    n.div_ceil(d)
}
