//! PJRT integration: load the AOT HLO-text artifacts, execute them on the
//! CPU PJRT client from rust, and compare against both the goldens and the
//! native engine. This is the L3←L2←L1 composition proof.
//!
//! Gated behind the off-by-default `pjrt` feature: the offline tier-1
//! build carries no crate registry, so the `xla` dependency closure must
//! be vendored before these tests can run.
#![cfg(feature = "pjrt")]

use flashomni::model::MiniMMDiT;
use flashomni::runtime::{load_param_list, ArtifactRuntime, Input};
use flashomni::tensor::Tensor;
use flashomni::util::fot::FotFile;

fn artifacts_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("mmdit_step.hlo.txt").exists() {
            return Some(dir.to_string());
        }
    }
    eprintln!("SKIP: artifacts not found — run `make artifacts`");
    None
}

#[test]
fn pjrt_attention_artifact_matches_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::cpu(&dir).unwrap();
    rt.load("attention_masked").unwrap();
    let g = FotFile::load(format!("{dir}/golden.fot")).unwrap();
    let q = Tensor::from_fot(&g, "attn.q").unwrap();
    let k = Tensor::from_fot(&g, "attn.k").unwrap();
    let v = Tensor::from_fot(&g, "attn.v").unwrap();
    let want = Tensor::from_fot(&g, "attn.out").unwrap();
    let s_c: Vec<i32> =
        g.get("attn.s_c").unwrap().to_u8().unwrap().iter().map(|&b| b as i32).collect();
    let s_s_t = g.get("attn.s_s").unwrap().clone();
    let s_s: Vec<i32> = s_s_t.to_u8().unwrap().iter().map(|&b| b as i32).collect();
    let out = rt
        .execute(
            "attention_masked",
            &[
                Input::F32(&q),
                Input::F32(&k),
                Input::F32(&v),
                Input::I32(&s_c, &[s_c.len()]),
                Input::I32(&s_s, &s_s_t.shape),
            ],
            &[q.shape()],
        )
        .unwrap();
    let diff = out[0].max_abs_diff(&want);
    assert!(diff < 1e-4, "PJRT attention vs golden: {diff}");
}

#[test]
fn pjrt_model_step_matches_native_engine() {
    // Execute the full trained model step on PJRT and compare with the
    // rust-native dense forward — the dual-engine agreement test.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::cpu(&dir).unwrap();
    rt.load("mmdit_step").unwrap();
    let params = load_param_list(&dir).unwrap();
    let model = MiniMMDiT::load(&format!("{dir}/weights.fot")).unwrap();
    let g = FotFile::load(format!("{dir}/golden.fot")).unwrap();
    let ids_raw = g.get("mmdit.ids").unwrap();
    let ids_i32: Vec<i32> = ids_raw
        .data
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let ids_usize: Vec<usize> = ids_i32.iter().map(|&i| i as usize).collect();
    let patches = Tensor::from_fot(&g, "mmdit.patches").unwrap();
    let shape = [model.cfg.vision_tokens(), model.cfg.patch_dim()];
    for t in [0.1f32, 0.5, 0.9] {
        let oracle = rt.mmdit_step(&params, &ids_i32, &patches, t, &shape).unwrap();
        let native = model.forward_dense(&ids_usize, &patches, t as f64);
        let rel = native.rel_l2(&oracle);
        assert!(rel < 1e-4, "t={t}: native vs PJRT rel-L2 {rel}");
    }
}

#[test]
fn pjrt_gemm_artifacts_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::cpu(&dir).unwrap();
    rt.load("gemm_q").unwrap();
    rt.load("gemm_o").unwrap();
    let g = FotFile::load(format!("{dir}/golden.fot")).unwrap();
    let x = Tensor::from_fot(&g, "gq.x").unwrap();
    let w = Tensor::from_fot(&g, "gq.w").unwrap();
    let want = Tensor::from_fot(&g, "gq.out").unwrap();
    let s_c_t = g.get("gq.s_c").unwrap().clone();
    let s_c: Vec<i32> = s_c_t.to_u8().unwrap().iter().map(|&b| b as i32).collect();
    let out = rt
        .execute(
            "gemm_q",
            &[Input::F32(&x), Input::F32(&w), Input::I32(&s_c, &s_c_t.shape)],
            &[x.shape()],
        )
        .unwrap();
    assert!(out[0].max_abs_diff(&want) < 1e-3);

    let o = Tensor::from_fot(&g, "go.o").unwrap();
    let wo = Tensor::from_fot(&g, "go.w").unwrap();
    let bias = Tensor::from_fot(&g, "go.bias").unwrap();
    let want = Tensor::from_fot(&g, "go.out").unwrap();
    let out = rt
        .execute(
            "gemm_o",
            &[
                Input::F32(&o),
                Input::F32(&wo),
                Input::F32(&bias),
                Input::I32(&s_c, &s_c_t.shape),
            ],
            &[o.shape()],
        )
        .unwrap();
    assert!(out[0].max_abs_diff(&want) < 1e-3);
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ArtifactRuntime::cpu(&dir).unwrap();
    assert!(rt.load("does_not_exist").is_err());
    assert!(rt.execute("unloaded", &[], &[]).is_err());
}

#[test]
fn pjrt_generator_matches_native_dense_generation() {
    // Full sampling loops on the two engines must agree: dual-engine
    // agreement at the *generation* level, not just per-step.
    let Some(dir) = artifacts_dir() else { return };
    let gen = flashomni::runtime::PjRtGenerator::load(&dir).unwrap();
    let model = MiniMMDiT::load(&format!("{dir}/weights.fot")).unwrap();
    let ids: Vec<usize> = flashomni::workload::caption_ids(7, model.cfg.text_tokens);
    let steps = 6;
    let (oracle_img, wall) = gen.generate(&ids, 3, steps).unwrap();
    assert!(wall > 0.0);
    let mut native = flashomni::engine::DiTEngine::new(
        model,
        flashomni::engine::Policy::full(),
        8,
        8,
    );
    let r = native.generate(&ids, 3, steps);
    let rel = r.image.rel_l2(&oracle_img);
    assert!(rel < 1e-3, "native vs PJRT full generation rel-L2 {rel}");
}
