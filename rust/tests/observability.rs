//! Observability-layer contract tests (`rust/src/obs/`):
//!
//! * engine outputs are **bitwise-identical** whether the metrics/trace
//!   knobs are on or off (telemetry must never perturb numerics);
//! * histogram bucketing and quantile interpolation are correct on known
//!   distributions;
//! * the Chrome trace export is valid JSON with per-track ordering and
//!   stack-discipline nesting, and carries the expected span vocabulary;
//! * the Prometheus dump covers the registry after an instrumented run.
//!
//! The gates and the registry are process-global, so every test that
//! flips them (or reads registry state it just produced) serializes on a
//! file-local mutex — the library's own unit tests run in a separate
//! process and cannot interfere.

use flashomni::batch::{BatchScheduler, BatchedEngine};
use flashomni::config::{ModelConfig, SparsityConfig};
use flashomni::engine::{DiTEngine, Policy};
use flashomni::model::{weights::Weights, MiniMMDiT};
use flashomni::obs;
use flashomni::obs::metrics::{bucket_hi, bucket_index, bucket_lo, Histogram, HIST_BUCKETS};
use flashomni::tensor::Tensor;
use flashomni::util::json::Json;
use flashomni::workload::poisson_trace;
use std::sync::Mutex;

/// Serializes the tests in this binary that touch the process-global
/// gates/registry/trace buffer.
static GATE: Mutex<()> = Mutex::new(());

fn tiny_model() -> MiniMMDiT {
    let cfg = ModelConfig {
        dim: 32,
        heads: 2,
        layers: 2,
        text_tokens: 8,
        patch_h: 4,
        patch_w: 4,
        patch_size: 2,
        channels: 3,
        mlp_ratio: 2,
        vocab: 256,
    };
    MiniMMDiT::new(cfg.clone(), Weights::random(&cfg, 0x0b5))
}

/// A policy that exercises dense warmup, sparse Dispatch steps and plan
/// refreshes in a 6-step run.
fn sparse_policy() -> Policy {
    Policy::flashomni(SparsityConfig {
        tau_q: 0.5,
        tau_kv: 0.2,
        interval: 3,
        order: 1,
        s_q: 0.0,
        block_q: 8,
        block_k: 8,
        pool: 1,
        warmup: 2,
        ramp_steps: 1,
    })
}

fn solo_image(model: &MiniMMDiT) -> Tensor {
    let mut engine = DiTEngine::new(model.clone(), sparse_policy(), 8, 8);
    let ids: Vec<usize> = (0..model.cfg.text_tokens).map(|i| (3 * i + 1) % 256).collect();
    engine.generate(&ids, 42, 6).image
}

fn batched_images(model: &MiniMMDiT) -> Vec<(u64, Tensor)> {
    let trace = poisson_trace(7, 3, 1000.0, 6, model.cfg.text_tokens);
    let mut sched =
        BatchScheduler::with_token_budget(BatchedEngine::new(model.clone(), sparse_policy(), 8, 8, 3), 0);
    for r in &trace {
        sched.submit(r.clone());
    }
    let mut out: Vec<(u64, Tensor)> =
        sched.run_to_completion().into_iter().map(|r| (r.id, r.image)).collect();
    out.sort_by_key(|(id, _)| *id);
    out
}

#[test]
fn outputs_bitwise_identical_with_and_without_obs() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let model = tiny_model();

    obs::set_metrics_enabled(Some(false));
    obs::set_trace_enabled(Some(false));
    let solo_off = solo_image(&model);
    let batch_off = batched_images(&model);

    obs::set_metrics_enabled(Some(true));
    obs::set_trace_enabled(Some(true));
    let solo_on = solo_image(&model);
    let batch_on = batched_images(&model);

    obs::set_metrics_enabled(None);
    obs::set_trace_enabled(None);
    flashomni::obs::trace::clear();

    assert_eq!(solo_off, solo_on, "solo output must not depend on the obs gates");
    assert_eq!(batch_off.len(), batch_on.len());
    for ((id_a, img_a), (id_b, img_b)) in batch_off.iter().zip(&batch_on) {
        assert_eq!(id_a, id_b);
        assert_eq!(img_a, img_b, "batched output of request {id_a} changed under obs");
    }
}

#[test]
fn histogram_buckets_and_quantiles() {
    // Pure data-structure test: a local histogram, no gate involved
    // (`record_ns` is deliberately unconditional).
    static H: Histogram = Histogram::new("fo_test_hist_ns", "test-only histogram");

    // Bucket boundary law: bucket i covers [2^i, 2^{i+1}), 0/1 ns share
    // bucket 0, and the top bucket absorbs everything else.
    for i in 1..HIST_BUCKETS {
        assert_eq!(bucket_index(bucket_lo(i)), i);
        assert_eq!(bucket_index(bucket_hi(i) - 1), i.min(HIST_BUCKETS - 1));
    }
    assert_eq!(bucket_index(0), 0);
    assert_eq!(bucket_index(1), 0);
    assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);

    // Known bimodal distribution: 1000 × 100ns, 1000 × 10_000ns.
    for _ in 0..1000 {
        H.record_ns(100);
    }
    for _ in 0..1000 {
        H.record_ns(10_000);
    }
    assert_eq!(H.count(), 2000);
    assert_eq!(H.sum_ns(), 1000 * 100 + 1000 * 10_000);

    // p50 must land in 100ns's bucket [64, 128); p99 in 10_000ns's bucket
    // [8192, 16384). Interpolation stays inside the bucket bounds.
    let p50 = H.quantile_ns(0.50);
    assert!((64.0..=128.0).contains(&p50), "p50 = {p50}");
    let p99 = H.quantile_ns(0.99);
    assert!((8192.0..=16384.0).contains(&p99), "p99 = {p99}");
    // Degenerate quantiles: q→0 stays in the lowest populated bucket,
    // q = 1 in the highest.
    let p0 = H.quantile_ns(0.001);
    assert!((0.0..=128.0).contains(&p0), "p~0 = {p0}");
    let p100 = H.quantile_ns(1.0);
    assert!((8192.0..=16384.0).contains(&p100), "p100 = {p100}");
    // Monotonicity across the sweep.
    let mut last = 0.0;
    for q in [0.1, 0.25, 0.5, 0.75, 0.9, 0.99] {
        let v = H.quantile_ns(q);
        assert!(v >= last, "quantiles must be monotone (q={q}: {v} < {last})");
        last = v;
    }
}

/// Timestamp-rounding slack in µs: ts/dur are serialized with 3 decimals
/// (ns precision), so ends can round apart by ≤ 1ns per endpoint.
const EPS_US: f64 = 0.005;

#[test]
fn trace_export_is_valid_nested_json() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let model = tiny_model();

    obs::set_metrics_enabled(Some(false));
    obs::set_trace_enabled(Some(true));
    flashomni::obs::trace::clear();
    let _ = solo_image(&model);
    let _ = batched_images(&model);
    obs::set_trace_enabled(None);
    obs::set_metrics_enabled(None);

    let json_text = flashomni::obs::trace::chrome_trace_json();
    flashomni::obs::trace::clear();
    let doc = Json::parse(&json_text).expect("trace must be valid JSON");

    assert_eq!(doc.get("displayTimeUnit").and_then(|v| v.as_str()), Some("ms"));
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("traceEvents array");
    assert!(events.len() > 2, "expected events beyond the two metadata records");

    // Metadata: both process tracks are named.
    let meta: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("M"))
        .collect();
    assert_eq!(meta.len(), 2, "one process_name record per track");

    // Slices: collect (pid, tid, ts, dur, name) in file order.
    let mut names: Vec<String> = Vec::new();
    let mut tracks: Vec<((u64, u64), Vec<(f64, f64)>)> = Vec::new();
    for e in events {
        if e.get("ph").and_then(|v| v.as_str()) != Some("X") {
            continue;
        }
        let pid = e.req("pid").unwrap().as_f64().unwrap() as u64;
        let tid = e.req("tid").unwrap().as_f64().unwrap() as u64;
        let ts = e.req("ts").unwrap().as_f64().unwrap();
        let dur = e.req("dur").unwrap().as_f64().unwrap();
        assert!(ts >= 0.0 && dur >= 0.0);
        names.push(e.req("name").unwrap().as_str().unwrap().to_string());
        match tracks.iter_mut().find(|(k, _)| *k == (pid, tid)) {
            Some((_, v)) => v.push((ts, dur)),
            None => tracks.push(((pid, tid), vec![(ts, dur)])),
        }
    }

    // Expected span vocabulary from a dense-warmup + sparse run.
    for expected in [
        "engine.step",
        "model.embed",
        "model.decode",
        "attention.dense",
        "gemm_q.dense",
        "gemm_o.dense",
        "mlp.dense",
        "request.queue_wait",
        "request.exec",
    ] {
        assert!(
            names.iter().any(|n| n == expected),
            "span name {expected:?} missing from the trace (got {names:?})"
        );
    }

    // Per-track ordering + stack-discipline nesting: slices on one track
    // are sorted by start time, and each slice is either disjoint from or
    // fully contained in the enclosing one.
    for ((pid, tid), slices) in &tracks {
        let mut stack: Vec<f64> = Vec::new(); // enclosing end timestamps
        let mut last_ts = f64::NEG_INFINITY;
        for &(ts, dur) in slices {
            assert!(
                ts >= last_ts,
                "track ({pid},{tid}): slices out of order ({ts} after {last_ts})"
            );
            last_ts = ts;
            let end = ts + dur;
            while let Some(&top) = stack.last() {
                if ts >= top - EPS_US {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(&top) = stack.last() {
                assert!(
                    end <= top + EPS_US,
                    "track ({pid},{tid}): slice [{ts}, {end}] straddles its enclosing \
                     slice ending at {top}"
                );
            }
            stack.push(end);
        }
    }

    // Request-lifecycle slices ride the dedicated track with tid = id.
    let request_tids: Vec<u64> = tracks
        .iter()
        .filter(|((pid, _), _)| *pid == flashomni::obs::trace::PID_REQUESTS as u64)
        .map(|((_, tid), _)| *tid)
        .collect();
    assert!(
        !request_tids.is_empty() && request_tids.iter().all(|t| *t < 3),
        "request track must carry tid = request id (got {request_tids:?})"
    );
}

#[test]
fn prometheus_dump_covers_registry_after_instrumented_run() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let model = tiny_model();

    obs::reset_metrics();
    obs::set_trace_enabled(Some(false));
    obs::set_metrics_enabled(Some(true));
    let _ = solo_image(&model);
    let _ = batched_images(&model);
    let steps = flashomni::obs::metrics::ENGINE_STEPS.get();
    let frac = obs::accounted_step_fraction();
    let text = obs::prometheus_text();
    obs::set_metrics_enabled(None);
    obs::set_trace_enabled(None);
    obs::reset_metrics();

    assert!(steps > 0, "instrumented run must count engine steps");
    // Accounted kernel regions are sub-intervals of engine.step, so the
    // coverage fraction is positive and cannot meaningfully exceed 1.
    assert!(frac > 0.0 && frac <= 1.05, "accounted step fraction {frac}");

    // Exposition-format shape: HELP/TYPE pairs and samples for every
    // instrument, cumulative buckets capped by +Inf.
    for name in [
        "fo_engine_steps_total",
        "fo_requests_enqueued_total",
        "fo_requests_admitted_total",
        "fo_requests_retired_total",
        "fo_plan_cache_misses_total",
        "fo_engine_step_ns",
        "fo_kernel_attention_dense_ns",
        "fo_model_embed_ns",
        "fo_request_exec_ns",
    ] {
        assert!(text.contains(&format!("# HELP {name} ")), "missing HELP for {name}");
        assert!(text.contains(&format!("# TYPE {name} ")), "missing TYPE for {name}");
    }
    assert!(text.contains("fo_engine_step_ns_bucket{le=\"+Inf\"}"));
    assert!(text.contains("fo_engine_step_ns_count"));
    assert!(text.contains("fo_engine_step_ns_sum"));
    // Every non-comment line is `name[{labels}] value`.
    for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
        let mut parts = line.rsplitn(2, ' ');
        let value = parts.next().unwrap();
        let name = parts.next().unwrap_or("");
        assert!(!name.is_empty(), "malformed sample line: {line:?}");
        assert!(
            value.parse::<f64>().is_ok(),
            "sample value not numeric in line: {line:?}"
        );
    }
}
