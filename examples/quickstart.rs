//! Quickstart: load the trained MiniMMDiT, generate one image densely and
//! once with FlashOmni, and compare quality + work.
//!
//! ```bash
//! make artifacts            # once: trains the toy model + AOT artifacts
//! cargo run --release --example quickstart
//! ```

use flashomni::config::SparsityConfig;
use flashomni::engine::{DiTEngine, Policy};
use flashomni::metrics;
use flashomni::model::MiniMMDiT;
use flashomni::workload::caption_ids;

fn main() -> Result<(), String> {
    let weights = std::env::args().nth(1).unwrap_or("artifacts/weights.fot".into());
    let model = MiniMMDiT::load(&weights)?;
    println!(
        "MiniMMDiT: {} params | seq {} ({} text + {} vision tokens) | {} layers",
        model.param_count(),
        model.cfg.seq_len(),
        model.cfg.text_tokens,
        model.cfg.vision_tokens(),
        model.cfg.layers
    );

    let scene = 123;
    let ids = caption_ids(scene, model.cfg.text_tokens);
    let steps = 20;

    // Dense reference.
    let mut dense = DiTEngine::new(model.clone(), Policy::full(), 8, 8);
    let r0 = dense.generate(&ids, 0, steps);
    println!("\ndense:     {:.3}s, sparsity 0%", r0.stats.wall_s);

    // FlashOmni with the paper's (50%, 15%, 5, 1, 30%) configuration.
    let policy = Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 5, 1, 0.3));
    let mut fo = DiTEngine::new(model, policy, 8, 8);
    let r1 = fo.generate(&ids, 0, steps);
    println!(
        "flashomni: {:.3}s, sparsity {:.1}%, FLOP speedup {:.2}x, wall speedup {:.2}x",
        r1.stats.wall_s,
        r1.stats.attn_sparsity() * 100.0,
        r1.stats.flop_speedup(),
        r0.stats.wall_s / r1.stats.wall_s
    );

    println!(
        "\nfidelity vs dense: PSNR {:.2} dB | SSIM {:.4} | RPIPS {:.4}",
        metrics::psnr(&r1.image, &r0.image),
        metrics::ssim(&r1.image, &r0.image),
        metrics::rpips(&r1.image, &r0.image)
    );
    println!("per-step attention density: {:?}",
        r1.stats.per_step_density.iter().map(|d| (d * 100.0).round() as i32).collect::<Vec<_>>());
    Ok(())
}
