//! Video-task example (Hunyuan substitute, DESIGN.md): generate a short
//! frame sequence under multi-granularity sparsity and score it with the
//! VBench-proxy metrics, comparing FlashOmni against dense and the
//! block-sparse baseline.
//!
//! ```bash
//! cargo run --release --example video_dispatch
//! ```

use flashomni::config::SparsityConfig;
use flashomni::engine::{DiTEngine, Policy, RunStats};
use flashomni::metrics;
use flashomni::model::MiniMMDiT;
use flashomni::report::merge_stats;
use flashomni::tensor::Tensor;
use flashomni::workload::video_frame_ids;

fn render_frames(
    model: &MiniMMDiT,
    policy: Policy,
    scene: usize,
    frames: usize,
    steps: usize,
) -> (Vec<Tensor>, RunStats) {
    let mut engine = DiTEngine::new(model.clone(), policy, 8, 8);
    let mut out = Vec::new();
    let mut agg = RunStats::default();
    for f in 0..frames {
        let ids = video_frame_ids(scene, f, model.cfg.text_tokens);
        let r = engine.generate(&ids, 99, steps);
        merge_stats(&mut agg, &r.stats);
        out.push(r.image);
    }
    (out, agg)
}

fn main() -> Result<(), String> {
    let model = MiniMMDiT::load("artifacts/weights.fot")?;
    let (frames_n, steps, scene) = (6, 16, 42);
    println!("video task: {frames_n} frames × {steps} steps, scene {scene}\n");

    let (dense, d_stats) = render_frames(&model, Policy::full(), scene, frames_n, steps);
    let cases: Vec<(Policy, &str)> = vec![
        (Policy::full(), "Full-Attention"),
        (Policy::sparge(0.06, 0.065, 4), "SpargeAttn"),
        (
            Policy::flashomni(SparsityConfig::paper(0.4, 0.01, 5, 1, 0.3)),
            "FlashOmni (40%,1%,5,1,30%)",
        ),
        (
            Policy::flashomni(SparsityConfig::paper(0.5, 0.05, 6, 1, 0.3)),
            "FlashOmni (50%,5%,6,1,30%)",
        ),
    ];
    println!(
        "{:<28} {:>7} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "method", "spars%", "speedup", "PSNR", "smooth", "consist", "flicker", "style"
    );
    for (policy, label) in cases {
        let (frames, stats) = render_frames(&model, policy, scene, frames_n, steps);
        let psnr = frames
            .iter()
            .zip(&dense)
            .map(|(a, b)| metrics::psnr(a, b).min(99.0))
            .sum::<f64>()
            / frames_n as f64;
        println!(
            "{label:<28} {:>7.1} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.4}",
            stats.attn_sparsity() * 100.0,
            d_stats.wall_s / stats.wall_s,
            psnr,
            metrics::smoothness(&frames),
            metrics::consistency(&frames),
            metrics::flicker(&frames),
            metrics::style(&frames),
        );
    }
    println!("\n(expected shape: FlashOmni keeps smoothness/consistency at dense level\n while SpargeAttn pays more quality for the same sparsity — Table 1 bottom)");
    Ok(())
}
