//! **End-to-end serving driver** (the DESIGN.md §5 validation run): load
//! the trained model, start the coordinator, replay a Poisson request
//! trace through both the dense engine and the FlashOmni engine, and
//! report latency / throughput / fidelity. Also runs one dense request
//! through the PJRT oracle path to show the artifacts compose at L3.
//!
//! ```bash
//! cargo run --release --example serve_image_gen
//! ```

use flashomni::config::SparsityConfig;
use flashomni::coordinator::replay_trace;
use flashomni::engine::{DiTEngine, Policy};
use flashomni::metrics;
use flashomni::model::MiniMMDiT;
use flashomni::workload::poisson_trace;

fn main() -> Result<(), String> {
    let weights = "artifacts/weights.fot";
    let model = MiniMMDiT::load(weights)?;
    let n_req = std::env::var("FO_REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(10usize);
    let steps = 16;
    let rate = 3.0; // requests/s
    let trace = poisson_trace(11, n_req, rate, steps, model.cfg.text_tokens);
    println!(
        "serving {n_req} requests, Poisson rate {rate}/s, {steps} denoising steps each\n"
    );

    // Dense baseline service.
    let m = model.clone();
    let (dense_rs, dense_rep) = replay_trace(
        move |_| DiTEngine::new(m.clone(), Policy::full(), 8, 8),
        &trace,
        1,
        4,
        1.0,
    );
    dense_rep.print("Full-Attention");

    // FlashOmni service.
    let m = model.clone();
    let policy = Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 5, 1, 0.3));
    let p2 = policy.clone();
    let (fo_rs, fo_rep) = replay_trace(
        move |_| DiTEngine::new(m.clone(), p2.clone(), 8, 8),
        &trace,
        1,
        4,
        1.0,
    );
    fo_rep.print(&policy.name());

    // Per-request fidelity of the sparse service vs the dense one.
    let mut psnr = 0.0;
    let mut ssim = 0.0;
    for d in &dense_rs {
        let f = fo_rs.iter().find(|r| r.id == d.id).unwrap();
        psnr += metrics::psnr(&f.image, &d.image).min(99.0);
        ssim += metrics::ssim(&f.image, &d.image);
    }
    println!(
        "\nfidelity (FlashOmni vs dense, {} requests): PSNR {:.2} dB | SSIM {:.4}",
        dense_rs.len(),
        psnr / dense_rs.len() as f64,
        ssim / dense_rs.len() as f64
    );
    println!(
        "exec-time speedup: {:.2}x | p50 latency improvement: {:.2}x",
        dense_rep.mean_exec_s / fo_rep.mean_exec_s,
        dense_rep.p50_latency_s / fo_rep.p50_latency_s
    );
    println!(
        "latency percentiles (FlashOmni): p50 {:.3}s | p95 {:.3}s | p99 {:.3}s",
        fo_rep.p50_latency_s, fo_rep.p95_latency_s, fo_rep.p99_latency_s
    );
    println!(
        "latency split (FlashOmni): queue p50 {:.3}s p99 {:.3}s | exec p50 {:.3}s p99 {:.3}s",
        fo_rep.p50_queue_s, fo_rep.p99_queue_s, fo_rep.p50_exec_s, fo_rep.p99_exec_s
    );
    // Batched-serving accounting: workers advance whole batches in
    // lockstep and share plan compiles per (layer, refresh).
    let compiles: u64 = fo_rs.iter().map(|r| r.stats.plan_cache_misses).sum();
    let hits: u64 = fo_rs.iter().map(|r| r.stats.plan_cache_hits).sum();
    let shared: u64 = fo_rs.iter().map(|r| r.stats.plan_cache_shared).sum();
    println!(
        "plan compiles: {compiles} ({hits} cache hits, {shared} shared within a batch step)"
    );

    // PJRT oracle path: one dense denoise step through the AOT artifact
    // (requires the off-by-default `pjrt` feature).
    pjrt_oracle_step(&model, &trace)?;
    // With FO_METRICS / FO_TRACE set, dump the Prometheus text and the
    // Perfetto-loadable Chrome trace for this serving run.
    for p in flashomni::obs::export_if_enabled() {
        println!("wrote {p}");
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_oracle_step(
    model: &MiniMMDiT,
    trace: &[flashomni::workload::Request],
) -> Result<(), String> {
    if !std::path::Path::new("artifacts/mmdit_step.hlo.txt").exists() {
        return Ok(());
    }
    use flashomni::runtime::{load_param_list, ArtifactRuntime};
    let mut rt = ArtifactRuntime::cpu("artifacts").map_err(|e| e.to_string())?;
    rt.load("mmdit_step").map_err(|e| e.to_string())?;
    let params = load_param_list("artifacts").map_err(|e| e.to_string())?;
    let patches = flashomni::diffusion::initial_noise(&model.cfg, 1);
    let ids: Vec<i32> = trace[0].prompt_ids.iter().map(|&i| i as i32).collect();
    let t0 = std::time::Instant::now();
    let v = rt
        .mmdit_step(
            &params,
            &ids,
            &patches,
            0.5,
            &[model.cfg.vision_tokens(), model.cfg.patch_dim()],
        )
        .map_err(|e| e.to_string())?;
    println!(
        "\nPJRT oracle step: {:.3}s, output norm {:.3} (artifact path live)",
        t0.elapsed().as_secs_f64(),
        v.data().iter().map(|x| (x * x) as f64).sum::<f64>().sqrt()
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_oracle_step(
    _model: &MiniMMDiT,
    _trace: &[flashomni::workload::Request],
) -> Result<(), String> {
    println!("\n(pjrt feature disabled — skipping the PJRT oracle step)");
    Ok(())
}
