//! Table 3 ablation as a standalone example: sweep the cache interval `N`
//! and the TaylorSeer order `D`, printing quality vs the dense baseline.
//!
//! ```bash
//! cargo run --release --example ablation_sweep [-- scenes steps]
//! ```

use flashomni::config::SparsityConfig;
use flashomni::engine::{DiTEngine, Policy};
use flashomni::metrics;
use flashomni::model::MiniMMDiT;
use flashomni::tensor::Tensor;
use flashomni::workload::{caption_ids, eval_scenes};

fn run_set(model: &MiniMMDiT, policy: Policy, scenes: &[usize], steps: usize) -> Vec<Tensor> {
    let mut engine = DiTEngine::new(model.clone(), policy, 8, 8);
    scenes
        .iter()
        .enumerate()
        .map(|(i, &s)| {
            engine
                .generate(&caption_ids(s, model.cfg.text_tokens), 500 + i as u64, steps)
                .image
        })
        .collect()
}

fn main() -> Result<(), String> {
    let args: Vec<String> = std::env::args().collect();
    let n_scenes: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(4);
    let steps: usize = args.get(2).and_then(|v| v.parse().ok()).unwrap_or(20);
    let model = MiniMMDiT::load("artifacts/weights.fot")?;
    let scenes = eval_scenes(n_scenes);
    println!("Table 3 ablation: {n_scenes} scenes × {steps} steps\n");

    let dense = run_set(&model, Policy::full(), &scenes, steps);
    let eval = |imgs: &[Tensor]| -> (f64, f64, f64) {
        let n = imgs.len() as f64;
        (
            imgs.iter().zip(&dense).map(|(a, b)| metrics::psnr(a, b).min(99.0)).sum::<f64>() / n,
            imgs.iter().zip(&dense).map(|(a, b)| metrics::ssim(a, b)).sum::<f64>() / n,
            imgs.iter().zip(&dense).map(|(a, b)| metrics::rpips(a, b)).sum::<f64>() / n,
        )
    };

    println!("{:<30} {:>8} {:>8} {:>9}", "config", "PSNR↑", "SSIM↑", "RPIPS↓");
    for n in 3..=7 {
        let p = Policy::flashomni(SparsityConfig::paper(0.05, 0.15, n, 1, 0.0));
        let (psnr, ssim, rpips) = eval(&run_set(&model, p, &scenes, steps));
        println!("(5%, 15%, N={n}, 1, 0)          {psnr:>8.3} {ssim:>8.4} {rpips:>9.4}");
    }
    println!();
    for d in 0..=2 {
        let p = Policy::flashomni(SparsityConfig::paper(0.5, 0.15, 5, d, 0.3));
        let (psnr, ssim, rpips) = eval(&run_set(&model, p, &scenes, steps));
        println!("(50%, 15%, 5, D={d}, 30%)        {psnr:>8.3} {ssim:>8.4} {rpips:>9.4}");
    }
    println!("\n(paper shape: quality degrades monotonically with N; D=1 ≥ D=0, D=2 ≈ D=1)");
    Ok(())
}
